"""Checkpoint atomicity / resume / retention / async."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.elastic import resume_or_init


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)},
    }


def _assert_tree_equal(x, y):
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        x, y)


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, extra={"data_step": 4})
    got, extra, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 3 and extra == {"data_step": 4}
    _assert_tree_equal(t, got)


def test_partial_write_is_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # forge a later, uncommitted (crashed) checkpoint
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 1
    _assert_tree_equal(t, got)


def test_retention(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(tmp_path, s, t, keep_last=2)
    committed = sorted(p.name for p in tmp_path.glob("step_*")
                       if (p / "COMMIT").exists())
    assert committed == ["step_00000004", "step_00000005"]


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save_async(tmp_path, 7, t)
    th.join()
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 7
    _assert_tree_equal(t, got)


def test_resume_or_init(tmp_path):
    t = _tree(5)
    abstract = jax.eval_shape(lambda: t)
    got, extra, start = resume_or_init(tmp_path, lambda: t, abstract)
    assert start == 0
    ckpt.save(tmp_path, 9, t, extra={"data_step": 10})
    got, extra, start = resume_or_init(tmp_path, lambda: _tree(1), abstract)
    assert start == 10
    _assert_tree_equal(t, got)


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 0, _tree())
    wrong = {"a": jax.ShapeDtypeStruct((5, 3), jnp.float32),
             "nested": {"b": jax.ShapeDtypeStruct((2,), jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, wrong)


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore re-shards (trivially, on 1 device)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    ckpt.save(tmp_path, 0, t)
    from repro import jax_compat

    mesh = jax_compat.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: t))
    got, _, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: t),
                             shardings=sh)
    _assert_tree_equal(t, got)
