"""Checkpoint atomicity / resume / retention / async."""
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.elastic import resume_or_init


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)},
    }


def _assert_tree_equal(x, y):
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        x, y)


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, extra={"data_step": 4})
    got, extra, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 3 and extra == {"data_step": 4}
    _assert_tree_equal(t, got)


def test_partial_write_is_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # forge a later, uncommitted (crashed) checkpoint
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 1
    _assert_tree_equal(t, got)


def _crash_save(root, step, tree, *, crash_after):
    """Replay `ckpt.save`'s write sequence and die at a chosen point.

    crash_after="tmp": after the tmp-dir write, before the rename (the
    classic kill-mid-save window); crash_after="rename": after the rename
    but before COMMIT (the narrower window the COMMIT file closes).
    """
    root = Path(root)
    tmp = root / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    for i, leaf in enumerate(leaves):
        np.save(tmp / f"arr_{i:05d}.npy", np.asarray(leaf))
    (tmp / "manifest.json").write_text(json.dumps({
        "step": step, "treedef": str(treedef), "n_leaves": len(leaves),
        "extra": {}, "leaves": [
            {"shape": list(np.asarray(x).shape),
             "dtype": str(np.asarray(x).dtype)} for x in leaves]}))
    if crash_after == "tmp":
        return tmp
    final = root / f"step_{step:08d}"
    tmp.rename(final)
    return final  # crashed before COMMIT


def test_crash_between_tmp_write_and_commit(tmp_path):
    """A kill anywhere in the save window never corrupts the last COMMIT:
    both crash points fall back to the previous committed step, and the
    abandoned tmp dir is swept by the next successful save."""
    t = _tree()
    ckpt.save(tmp_path, 1, t, extra={"cursor": 11})

    # crash point A: tmp fully written, rename never happened
    junk_tmp = _crash_save(tmp_path, 2, _tree(9), crash_after="tmp")
    # crash point B: renamed into place, COMMIT never written
    _crash_save(tmp_path, 3, _tree(9), crash_after="rename")

    assert ckpt.latest_step(tmp_path) == 1
    got, extra, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 1 and extra == {"cursor": 11}
    _assert_tree_equal(t, got)

    # the junk tmp dir is pruned by the next save once it is stale
    # (age-guarded so a live concurrent save_async writer is never raced)
    old = time.time() - 3600
    os.utime(junk_tmp, (old, old))
    ckpt.save(tmp_path, 4, t)
    assert not junk_tmp.exists()
    assert ckpt.latest_step(tmp_path) == 4


def test_fresh_tmp_dir_survives_sweep(tmp_path):
    """A tmp dir younger than the staleness window is left alone."""
    t = _tree()
    live_tmp = _crash_save(tmp_path, 7, t, crash_after="tmp")
    ckpt.save(tmp_path, 8, t)
    assert live_tmp.exists()


def test_extra_validation():
    assert ckpt.validate_extra(None) == {}
    # normalization happens before the write: tuples come back as lists
    assert ckpt.validate_extra({"cursor": (1, 2)}) == {"cursor": [1, 2]}
    with pytest.raises(TypeError, match="extra\\['bad'\\]"):
        ckpt.validate_extra({"bad": np.zeros(3)})
    with pytest.raises(TypeError, match="dict"):
        ckpt.validate_extra([1, 2])


def test_save_rejects_bad_extra_before_writing(tmp_path):
    with pytest.raises(TypeError):
        ckpt.save(tmp_path, 0, _tree(), extra={"arr": np.zeros(2)})
    assert list(tmp_path.glob("step_*")) == []  # fail-fast: nothing on disk


def test_retention(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(tmp_path, s, t, keep_last=2)
    committed = sorted(p.name for p in tmp_path.glob("step_*")
                       if (p / "COMMIT").exists())
    assert committed == ["step_00000004", "step_00000005"]


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save_async(tmp_path, 7, t)
    th.join()
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 7
    _assert_tree_equal(t, got)


def test_resume_or_init(tmp_path):
    t = _tree(5)
    abstract = jax.eval_shape(lambda: t)
    got, extra, start = resume_or_init(tmp_path, lambda: t, abstract)
    assert start == 0
    ckpt.save(tmp_path, 9, t, extra={"data_step": 10})
    got, extra, start = resume_or_init(tmp_path, lambda: _tree(1), abstract)
    assert start == 10
    _assert_tree_equal(t, got)


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 0, _tree())
    wrong = {"a": jax.ShapeDtypeStruct((5, 3), jnp.float32),
             "nested": {"b": jax.ShapeDtypeStruct((2,), jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, wrong)


def test_crc_mismatch_raises_corrupt_error(tmp_path):
    """Silent bit-rot in a committed array is caught by the per-leaf CRC."""
    t = _tree()
    final = ckpt.save(tmp_path, 0, t)
    f = sorted(final.glob("arr_*.npy"))[0]
    data = bytearray(f.read_bytes())
    data[-1] ^= 0x01  # payload byte, past the .npy header
    f.write_bytes(bytes(data))
    with pytest.raises(ckpt.CorruptCheckpointError, match="CRC mismatch"):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    # verify=False restores the (corrupt) bytes without complaint — the
    # chain-walking caller decides, not the primitive
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t),
                                verify=False)
    assert step == 0


def test_v1_manifest_restores_unverified(tmp_path):
    """Pre-CRC (format v1) checkpoints still restore — back-compat."""
    t = _tree()
    final = ckpt.save(tmp_path, 0, t)
    mpath = final / "manifest.json"
    m = json.loads(mpath.read_text())
    m.pop("format_version", None)
    for leaf in m["leaves"]:
        leaf.pop("crc32", None)
    mpath.write_text(json.dumps(m))
    got, _, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 0
    _assert_tree_equal(t, got)


def test_unreadable_manifest_raises_corrupt_error(tmp_path):
    t = _tree()
    final = ckpt.save(tmp_path, 0, t)
    (final / "manifest.json").write_text('{"half": tru')
    with pytest.raises(ckpt.CorruptCheckpointError, match="manifest"):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: t))


def test_async_save_error_surfaces(tmp_path):
    """Regression: a failing background save must NOT die silently with
    its daemon thread — the error re-raises from `wait_pending` and from
    the next save call."""
    (tmp_path / "blocker").write_text("i am a file, not a directory")
    bad_root = tmp_path / "blocker" / "ckpt"  # mkdir → ENOTDIR, as root too
    t = _tree()
    th = ckpt.save_async(bad_root, 0, t)
    th.join()
    with pytest.raises(OSError):
        ckpt.wait_pending()
    # drained: a subsequent healthy save is clean
    assert ckpt.wait_pending() == []
    ckpt.save(tmp_path / "ok", 1, t)

    # the same failure also surfaces at the *next* save call, for callers
    # that never explicitly drain
    ckpt.save_async(bad_root, 2, t).join()
    with pytest.raises(OSError):
        ckpt.save(tmp_path / "ok", 3, t)
    assert ckpt.wait_pending(raise_errors=False) == []


def test_wait_pending_collects_without_raising(tmp_path):
    (tmp_path / "blocker").write_text("x")
    bad_root = tmp_path / "blocker" / "ckpt"
    ckpt.save_async(bad_root, 0, _tree()).join()
    errs = ckpt.wait_pending(raise_errors=False)
    assert len(errs) == 1 and isinstance(errs[0], OSError)


def test_transient_io_error_is_retried(tmp_path):
    """Injected EIO on the first two attempts: the third succeeds, and each
    retry lands in RunHealth."""
    from repro.core import RunHealth
    from repro.runtime import FaultPlan, FaultSpec, faults

    t = _tree()
    health = RunHealth()
    faults.install(FaultPlan(
        [FaultSpec("save.io", "io_error", at=1, times=2, errno_name="EIO")]))
    try:
        ckpt.save(tmp_path, 0, t, retries=2, retry_backoff_s=0.0,
                  health=health)
    finally:
        faults.clear()
    assert ckpt.latest_step(tmp_path) == 0
    assert health.count("save_retry") == 2

    # beyond the retry budget the error propagates (it is not transient
    # forever) — and a *non*-transient errno never retries at all
    faults.install(FaultPlan(
        [FaultSpec("save.io", "io_error", at=1, times=99,
                   errno_name="ENOSPC")]))
    try:
        with pytest.raises(OSError):
            ckpt.save(tmp_path, 1, t, retries=2, retry_backoff_s=0.0)
    finally:
        faults.clear()
    faults.install(FaultPlan(
        [FaultSpec("save.io", "io_error", at=1, errno_name="EACCES")]))
    try:
        health2 = RunHealth()
        with pytest.raises(PermissionError):
            ckpt.save(tmp_path, 2, t, retries=2, retry_backoff_s=0.0,
                      health=health2)
        assert health2.count("save_retry") == 0
    finally:
        faults.clear()


def test_stale_ttl_configurable(tmp_path, monkeypatch):
    """The abandoned-tmp sweep TTL comes from the arg, then the env var,
    then the 60s default."""
    t = _tree()
    junk = _crash_save(tmp_path, 5, t, crash_after="tmp")
    old = time.time() - 10
    os.utime(junk, (old, old))
    # default TTL (60s): a 10s-old tmp survives
    ckpt.save(tmp_path, 6, t)
    assert junk.exists()
    # per-call override: now it is stale
    ckpt.save(tmp_path, 7, t, stale_tmp_s=5.0)
    assert not junk.exists()
    # env override works the same way
    junk2 = _crash_save(tmp_path, 8, t, crash_after="tmp")
    os.utime(junk2, (old, old))
    monkeypatch.setenv(ckpt.STALE_TMP_ENV, "5")
    ckpt.save(tmp_path, 9, t)
    assert not junk2.exists()


def test_sweep_never_touches_this_process_live_tmp(tmp_path):
    """A tmp dir registered as in-flight by this process is excluded from
    the sweep even when it looks ancient — an aggressive TTL can never
    race a live `save_async` writer."""
    t = _tree()
    live = _crash_save(tmp_path, 5, t, crash_after="tmp")
    old = time.time() - 3600
    os.utime(live, (old, old))
    with ckpt._ACTIVE_LOCK:
        ckpt._ACTIVE_TMP.add(live)
    try:
        ckpt.save(tmp_path, 6, t, stale_tmp_s=0.0)
        assert live.exists()
    finally:
        with ckpt._ACTIVE_LOCK:
            ckpt._ACTIVE_TMP.discard(live)
    # deregistered (writer finished/died), same TTL: now it is swept
    ckpt.save(tmp_path, 7, t, stale_tmp_s=0.0)
    assert not live.exists()


def test_committed_steps_ascending(tmp_path):
    t = _tree()
    for s in (4, 1, 9):
        ckpt.save(tmp_path, s, t, keep_last=10)
    _crash_save(tmp_path, 12, t, crash_after="rename")  # no COMMIT
    assert ckpt.committed_steps(tmp_path) == [1, 4, 9]


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore re-shards (trivially, on 1 device)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    ckpt.save(tmp_path, 0, t)
    from repro import jax_compat

    mesh = jax_compat.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: t))
    got, _, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: t),
                             shardings=sh)
    _assert_tree_equal(t, got)
