"""AdamW + schedule + ZeRO-1 spec tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (
    AdamWConfig, adamw_init, adamw_update, global_norm, schedule, zero1_specs,
)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    loss_fn = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        grads = jax.grad(loss_fn)(params)
        params, state = adamw_update(cfg, grads, state, params)
    assert float(loss_fn(params)) < 1e-3
    assert int(state.step) == 150


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(5))) == 0.5
    end = float(schedule(cfg, jnp.int32(100)))
    assert abs(end - 0.1) < 1e-5


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    big = {"w": jnp.full(4, 1e6)}
    p2, s2 = adamw_update(cfg, big, state, params)
    # first-step Adam update magnitude ≈ lr regardless of grad scale
    assert float(jnp.abs(p2["w"]).max()) < 2 * cfg.lr


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_zero1_specs_moves_to_data_axis():
    from jax.sharding import PartitionSpec as P

    from repro import jax_compat

    mesh = jax_compat.make_mesh((1, 1), ("data", "model"))
    # data axis size 1 → no change
    specs = {"w": P(None, "model")}
    abst = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    out = zero1_specs(specs, abst, mesh=mesh)
    assert out["w"] == P(None, "model")
