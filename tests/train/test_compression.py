"""Gradient compression: error bounds + error feedback + psum path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (
    compress_tree, compression_init, compressed_psum, decompress_tree,
)


def test_quantization_error_bound():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    q, s, _ = compress_tree(g)
    back = decompress_tree(q, s)
    max_abs = float(jnp.abs(g["w"]).max())
    # int8 symmetric quantization: error ≤ scale/2 = max/254
    assert float(jnp.abs(back["w"] - g["w"]).max()) <= max_abs / 254 + 1e-7


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64,)) * 0.01 + 5.0, jnp.float32)}
    state = compression_init(g)
    acc_fb = jnp.zeros(64)
    for _ in range(50):
        q, s, state = compress_tree(g, state)
        acc_fb += decompress_tree(q, s)["w"]
    # with error feedback, the running mean converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc_fb / 50), np.asarray(g["w"]),
                               rtol=1e-3, atol=1e-3)


def test_compressed_psum_single_device():
    from repro import jax_compat

    mesh = jax_compat.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}

    def f(g):
        out, _ = compressed_psum(g, "data")
        return out

    got = jax_compat.shard_map(
        f, mesh=mesh, in_specs=({"w": jax.sharding.PartitionSpec()},),
        out_specs={"w": jax.sharding.PartitionSpec()})(g)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(g["w"]),
                               atol=0.02)
