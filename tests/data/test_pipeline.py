"""Synthetic data + neighbor sampler tests."""
import numpy as np

from repro.data.sampler import NeighborSampler
from repro.data.synthetic import (
    PAPER_DATASETS, dlrm_batches, paper_dataset, rmat_graph, token_stream,
)


def test_rmat_sizes_and_determinism():
    g1 = rmat_graph(500, 3000, n_labels=4, seed=7)
    g2 = rmat_graph(500, 3000, n_labels=4, seed=7)
    assert g1.n == 500
    assert 0 < g1.n_edges <= 3000
    np.testing.assert_array_equal(g1.labels, g2.labels)
    np.testing.assert_array_equal(g1.out_indices, g2.out_indices)
    # degree skew exists (power-law-ish): max degree >> mean
    deg = np.diff(g1.out_indptr)
    assert deg.max() >= 4 * max(deg.mean(), 1)


def test_paper_dataset_scaling():
    g = paper_dataset("gnutella", scale=0.05)
    cfg = PAPER_DATASETS["gnutella"]
    assert abs(g.n - cfg["n"] * 0.05) < 16
    assert g.n_labels == cfg["n_labels"]
    assert g.undirected


def test_token_stream_resumable():
    s1 = token_stream(100, 2, 8, seed=3)
    batches = [next(s1) for _ in range(5)]
    s2 = token_stream(100, 2, 8, seed=3, start_step=3)
    t3 = next(s2)
    np.testing.assert_array_equal(batches[3][0], t3[0])
    np.testing.assert_array_equal(batches[3][1], t3[1])
    # targets are next-token shifted
    tok, tgt = batches[0]
    assert tok.shape == tgt.shape == (2, 8)


def test_dlrm_batches():
    from repro.configs.recsys import REDUCED

    it = dlrm_batches(REDUCED, 16, seed=1)
    b = next(it)
    assert b["dense"].shape == (16, REDUCED.n_dense)
    assert b["sparse_idx"].shape == (16, REDUCED.n_sparse, REDUCED.n_hot)
    assert b["sparse_idx"].max() < REDUCED.table_rows
    assert set(np.unique(b["labels"])) <= {0, 1}


def test_neighbor_sampler_block_validity():
    g = rmat_graph(300, 2500, n_labels=2, seed=2)
    s = NeighborSampler(g, fanout=(5, 3), batch_nodes=32, seed=0)
    blk = s.sample(step=0)
    # static caps respected
    assert blk.node_ids.shape == (s.node_cap,)
    assert blk.edge_src.shape == (s.edge_cap,)
    n, e = blk.n_real_nodes, blk.n_real_edges
    assert 0 < n <= s.node_cap and 0 <= e <= s.edge_cap
    # local indices in range; every edge endpoint is a real node
    assert blk.edge_src[:e].max() < n and blk.edge_dst[:e].max() < n
    # seeds are exactly the loss nodes
    assert blk.node_mask.sum() == 32
    # fanout bound: each seed aggregates ≤ fanout[0] messages at hop 1
    # (dst side of hop-1 edges are seeds)
    hop1_dst = blk.edge_dst[:32 * 5]
    # determinism
    blk2 = s.sample(step=0)
    np.testing.assert_array_equal(blk.node_ids, blk2.node_ids)
    blk3 = s.sample(step=1)
    assert not np.array_equal(blk.node_ids, blk3.node_ids)


def test_sampler_edges_point_neighbor_to_seed():
    g = rmat_graph(200, 1500, n_labels=2, seed=5)
    s = NeighborSampler(g, fanout=(4,), batch_nodes=16, seed=1)
    blk = s.sample(0)
    e = blk.n_real_edges
    for i in range(min(e, 50)):
        src_g = blk.node_ids[blk.edge_src[i]]
        dst_g = blk.node_ids[blk.edge_dst[i]]
        # sampled from dst's out-neighborhood: (dst → src) is a graph edge
        assert g.has_edge(int(dst_g), int(src_g))
