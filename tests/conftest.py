"""Shared fixtures/strategies. NOTE: no XLA_FLAGS here — tests see 1 device."""
import numpy as np
import pytest

try:  # real hypothesis when installed (CI); frozen containers use the shim
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
from hypothesis import strategies as st

from repro.core import Pattern, build_graph


# ---------------------------------------------------------------------------
# hypothesis strategies
# ---------------------------------------------------------------------------

@st.composite
def patterns(draw, min_k=2, max_k=5, n_labels=3, connected=True):
    """Random connected directed labeled pattern."""
    k = draw(st.integers(min_k, max_k))
    labels = draw(st.lists(st.integers(0, n_labels - 1), min_size=k, max_size=k))
    adj = np.zeros((k, k), dtype=bool)
    # spanning structure first (guarantees connectivity)
    for v in range(1, k):
        u = draw(st.integers(0, v - 1))
        if draw(st.booleans()):
            adj[u, v] = True
        else:
            adj[v, u] = True
    # extra edges
    for i in range(k):
        for j in range(k):
            if i != j and not adj[i, j] and draw(st.integers(0, 3)) == 0:
                adj[i, j] = True
    return Pattern(adj, np.array(labels, np.int32))


@st.composite
def data_graphs(draw, min_n=4, max_n=24, n_labels=3, p_edge_denom=4):
    """Random directed labeled data graph."""
    n = draw(st.integers(min_n, max_n))
    labels = draw(st.lists(st.integers(0, n_labels - 1), min_size=n, max_size=n))
    edges = []
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) < (1.0 / p_edge_denom)
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    edges = np.stack([src, dst], axis=1)
    return build_graph(n, edges, labels, n_labels=n_labels)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
