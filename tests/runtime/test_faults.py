"""Deterministic fault-matrix chaos tests (ISSUE 9 acceptance).

The headline property: for every fault class at every injection point —
torn tmp writes, silent array bit-rot, manifest corruption, transient
EIO/ENOSPC, crash-inside-save, kill-at-snapshot — a checkpointed mining
run completes (restarting on injected kills, exactly like the CI
resume-smoke loop) and its frequent set + supports are **bit-identical**
to the fault-free oracle, with every recovery recorded in `RunHealth`.

Also covered here: graceful degradation (overflow-escalation restoring
forced-plane equality under an auto-derived cap that overflows;
distributed→batched plane fallback), COMMIT-chain fallback per corrupted
artifact, and in-process preemption.  Checkpoint-layer unit tests (CRC,
retry/backoff, async error surfacing, stale-tmp sweep) live in
tests/train/test_checkpoint.py.

Graphs are tiny on purpose — every fault cell re-mines the graph at least
once, and the contract is structural, not scale-dependent.
"""
import dataclasses

import pytest

from repro.core import MatchConfig, MiningConfig, mine
from repro.core import planner as planner_lib
from repro.data.synthetic import rmat_graph
from repro.runtime import (
    FaultPlan, FaultSpec, InjectedCrash, MiningSession, PreemptedError,
    faults,
)
from repro.train import checkpoint as ckpt


@pytest.fixture(autouse=True)
def _no_leaked_fault_plan():
    faults.clear()
    yield
    faults.clear()


def _graph():
    return rmat_graph(64, 320, n_labels=2, seed=3, undirected=True)


def _match_cfg():
    return MatchConfig(cap=512, root_block=16, chunk=16, max_chunks=4,
                       bisect_iters=7)


def _cfg(metric="mis", **kw):
    kw.setdefault("sigma", 6)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    kw.setdefault("match", _match_cfg())
    return MiningConfig(metric=metric, **kw)


def _norm(res, *, drop_level_keys=("wall_s",)):
    """Everything in a MiningResult except wall-clock (and health)."""
    return dict(
        frequent=[(p.key(), s) for p, s in res.frequent],
        searched=res.searched,
        stats=[(st.pattern.key(), st.support, st.tau, st.frequent,
                st.embeddings_found, st.overflowed, st.blocks_run)
               for st in res.stats],
        per_level={k: {kk: vv for kk, vv in v.items()
                       if kk not in drop_level_keys}
                   for k, v in res.per_level.items()},
        timed_out=res.timed_out,
        peak=res.peak_device_bytes,
    )


def _supports(res):
    return sorted((p.key(), int(s)) for p, s in res.frequent)


def _run_with_faults(g, cfg, ckpt_dir, plan, *, max_restarts=10, **kw):
    """The chaos driver: install the plan, mine, restart on injected
    kills (the in-process analogue of the CI kill+resume loop)."""
    faults.install(plan)
    restarts = 0
    try:
        while True:
            sess = MiningSession(g, cfg, ckpt_dir, **kw)
            try:
                return sess.run()
            except InjectedCrash:
                restarts += 1
                assert restarts <= max_restarts, (
                    f"fault driver livelocked after {restarts} restarts: "
                    f"{plan.fired}")
    finally:
        faults.clear()


# ---------------------------------------------------------------------------
# the fault × injection-point matrix
# ---------------------------------------------------------------------------

# (id, fault specs, health event kind the recovery must record — None when
# the recovery is the COMMIT protocol itself, which is silent by design)
MATRIX = [
    ("save-io-eio-transient",
     [FaultSpec("save.io", "io_error", at=2, errno_name="EIO")],
     "save_retry"),
    ("save-io-enospc-transient",
     [FaultSpec("save.io", "io_error", at=1, times=2,
                errno_name="ENOSPC")],
     "save_retry"),
    ("torn-array-write",
     [FaultSpec("save.array_write", "torn_write", at=2)],
     None),
    ("manifest-corruption-then-kill",
     [FaultSpec("save.manifest", "corrupt_manifest", at=3),
      FaultSpec("session.snapshot", "crash", at=3)],
     "restore_fallback"),
    ("array-bitflip-then-kill",
     [FaultSpec("save.committed", "bitflip", at=3),
      FaultSpec("session.snapshot", "crash", at=3)],
     "restore_fallback"),
    ("crash-inside-save",
     [FaultSpec("save.pre_commit", "crash", at=2)],
     None),
    ("kill-at-first-snapshot",
     [FaultSpec("session.snapshot", "crash", at=1)],
     None),
    ("kill-at-later-snapshot",
     [FaultSpec("session.snapshot", "crash", at=4)],
     None),
]


@pytest.fixture(scope="module")
def oracle():
    g, cfg = _graph(), _cfg(execution="batched")
    return mine(g, cfg)


@pytest.mark.parametrize("specs,expect",
                         [m[1:] for m in MATRIX],
                         ids=[m[0] for m in MATRIX])
def test_fault_matrix_bit_identical(tmp_path, oracle, specs, expect):
    g, cfg = _graph(), _cfg(execution="batched")
    plan = FaultPlan(specs, seed=7)
    res = _run_with_faults(g, cfg, tmp_path, plan,
                           checkpoint_every=1, keep_last=3)
    assert plan.fired, "no fault fired — the matrix cell tested nothing"
    assert _norm(res) == _norm(oracle)
    if expect is not None:
        assert res.health.count(expect) >= 1, res.health.to_dict()


def test_fault_matrix_cells_cover_every_point():
    """The matrix exercises every checkpoint/session injection point (the
    distributed-plane point has its own fallback tests below)."""
    covered = {s.point for _, specs, _ in MATRIX for s in specs}
    assert covered == {p for p in faults.POINTS if p != "level.distributed"}


# ---------------------------------------------------------------------------
# COMMIT-chain fallback, per corrupted artifact of the newest step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("artifact", ["array", "manifest", "commit"])
def test_chain_fallback_per_artifact(tmp_path, oracle, artifact):
    """Kill a session mid-level, corrupt one artifact of its newest step,
    resume: `load_session` recovers from the previous committed step —
    reported in RunHealth (except a missing COMMIT, which the protocol
    already treats as 'never happened')."""
    g, cfg = _graph(), _cfg(execution="batched")
    faults.install(FaultPlan(
        [FaultSpec("session.snapshot", "crash", at=3)]))
    try:
        with pytest.raises(InjectedCrash):
            MiningSession(g, cfg, tmp_path, checkpoint_every=1,
                          keep_last=100).run()
    finally:
        faults.clear()
    ckpt.wait_pending(raise_errors=False)
    steps = ckpt.committed_steps(tmp_path)
    assert len(steps) >= 2, "need a retained chain to fall back across"
    newest = tmp_path / f"step_{steps[-1]:08d}"
    if artifact == "array":
        # a mid-level snapshot carries the in-flight group's device arrays
        arrs = [f for f in sorted(newest.glob("arr_*.npy"))
                if f.stat().st_size > 128]
        assert arrs, "expected a payload-bearing mid-level snapshot"
        data = bytearray(arrs[0].read_bytes())
        data[-1] ^= 0x01  # silent payload rot — only the CRC can see it
        arrs[0].write_bytes(bytes(data))
    elif artifact == "manifest":
        (newest / "manifest.json").write_text('{"half": tru')
    else:
        (newest / "COMMIT").unlink()

    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=1,
                            keep_last=100).run()
    assert _norm(resumed) == _norm(oracle)
    if artifact != "commit":
        assert resumed.health.count("restore_fallback") >= 1, \
            resumed.health.to_dict()
    if artifact == "array":
        assert resumed.health.count("checksum_mismatch") >= 1, \
            resumed.health.to_dict()


def test_chain_fallback_every_step_corrupt_degrades_to_fresh(tmp_path):
    """Worst case: the whole retained chain is corrupt — the session
    starts fresh (degraded, never wrong) and records every skipped step."""
    g, cfg = _graph(), _cfg(execution="batched")
    ref = MiningSession(g, cfg, tmp_path, checkpoint_every=0,
                        keep_last=100).run()
    steps = ckpt.committed_steps(tmp_path)
    assert steps
    for s in steps:
        (tmp_path / f"step_{s:08d}" / "manifest.json").write_text("junk")
    again = MiningSession(g, cfg, tmp_path, checkpoint_every=0,
                          keep_last=100).run()
    assert _norm(again) == _norm(ref)
    assert again.health.count("restore_fallback") == len(steps), \
        again.health.to_dict()


# ---------------------------------------------------------------------------
# graceful degradation: overflow escalation (auto-derived cap overflowed)
# ---------------------------------------------------------------------------

def test_overflow_escalation_restores_forced_plane_equality(monkeypatch):
    """ISSUE 9 acceptance: on a graph whose auto-derived cap overflows,
    the escalation pass re-runs just the overflowed patterns at base cap
    and the auto result equals forced batched bit-for-bit — closing the
    'preserves results whenever no level overflows the derived cap'
    equality hole.  CAP_FLOOR/CAP_HEADROOM are squeezed so the planner
    right-sizes aggressively enough to overflow on a tiny graph."""
    monkeypatch.setattr(planner_lib, "CAP_FLOOR", 1)
    monkeypatch.setattr(planner_lib, "CAP_HEADROOM", 1)
    g = rmat_graph(96, 700, n_labels=1, seed=11, undirected=True)
    base = MatchConfig(cap=8192, root_block=16, chunk=16, max_chunks=4,
                       bisect_iters=7)
    cfg_auto = MiningConfig(sigma=6, lam=1.0, metric="mis", complete=True,
                            max_pattern_size=3, match=base,
                            execution="auto")
    cfg_forced = dataclasses.replace(cfg_auto, execution="batched")
    res_auto = mine(g, cfg_auto)
    res_forced = mine(g, cfg_forced)
    # the premise: some level really did overflow its derived cap
    assert res_auto.health.count("overflow_escalation") >= 1, \
        res_auto.health.to_dict()
    # the property: equality anyway ("plan" is auto-only; dispatch counts
    # legitimately include the escalation re-runs; peak_device_bytes is an
    # accounting property of the executed geometry — derived cap vs base
    # cap — not of the mined result)
    drop = ("wall_s", "plan", "dispatches")
    na = _norm(res_auto, drop_level_keys=drop)
    nf = _norm(res_forced, drop_level_keys=drop)
    na.pop("peak")
    nf.pop("peak")
    assert na == nf


# ---------------------------------------------------------------------------
# graceful degradation: distributed → batched plane fallback
# ---------------------------------------------------------------------------

def test_distributed_fallback_to_batched():
    """Every distributed level failing degrades the whole run to the
    batched plane — full bit-identity with forced batched, plus a
    plane_fallback health event per level."""
    g = _graph()
    cfg = _cfg("mis_luby", execution="distributed")
    oracle = mine(g, dataclasses.replace(cfg, execution="batched"))
    faults.install(FaultPlan(
        [FaultSpec("level.distributed", "error", at=1, times=99)]))
    try:
        res = mine(g, cfg)
    finally:
        faults.clear()
    assert res.health.count("plane_fallback") >= 1, res.health.to_dict()
    assert _norm(res) == _norm(oracle)


def test_distributed_fallback_session_killed_and_resumed(tmp_path):
    """A session killed mid-level *after* the plane fallback resumes onto
    the rewritten (batched) plan — the recorded plan overrides the forced
    distributed execution for the in-flight level."""
    g = _graph()
    cfg = _cfg("mis_luby", execution="distributed")
    oracle = mine(g, dataclasses.replace(cfg, execution="batched"))
    plan = FaultPlan([
        FaultSpec("level.distributed", "error", at=1, times=99),
        FaultSpec("session.snapshot", "crash", at=2),
    ])
    res = _run_with_faults(g, cfg, tmp_path, plan, checkpoint_every=1,
                           keep_last=100)
    assert any(f["point"] == "session.snapshot" for f in plan.fired)
    assert res.health.count("plane_fallback") >= 1
    assert _supports(res) == _supports(oracle)


# ---------------------------------------------------------------------------
# preemption (in-process half; the SIGTERM/CLI half lives in tests/launch)
# ---------------------------------------------------------------------------

def test_preempt_cuts_committed_snapshot_and_resumes(tmp_path):
    g, cfg = _graph(), _cfg(execution="batched")
    oracle = mine(g, cfg)
    sess = MiningSession(g, cfg, tmp_path, checkpoint_every=1)
    sess.request_preempt()
    with pytest.raises(PreemptedError):
        sess.run()
    # the preempted run left a consistent, committed snapshot…
    assert ckpt.latest_step(tmp_path) is not None
    assert sess.health.count("preempted") == 1
    # …that a later session resumes to the bit-identical result
    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=1).run()
    assert _norm(resumed) == _norm(oracle)


def test_fault_plan_env_roundtrip(monkeypatch):
    """CI drives subprocess chaos through REPRO_FAULT_PLAN."""
    monkeypatch.setenv(
        faults.FAULT_PLAN_ENV,
        '{"seed": 3, "faults": [{"point": "session.snapshot", '
        '"kind": "crash", "at": 2, "times": 1}]}')
    faults.clear()  # re-arm env pickup
    plan = faults.active()
    assert plan is not None and plan.seed == 3
    assert plan.specs == [FaultSpec("session.snapshot", "crash", at=2)]
    faults.clear()
