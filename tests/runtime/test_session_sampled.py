"""Sampled-plane sessions — resume replays the identical draw (ISSUE 7).

The contract: a sampled-plane run killed at *any* snapshot point — after
any sample-pass group, at the classification snapshot, inside the exact
escalation pass, or at a level boundary — and resumed from disk replays
the identical sample schedule and RNG chain and reproduces the
uninterrupted result bit-for-bit; and the sampled knobs join the session
fingerprint, so a resume under a different ``sample_fraction`` raises
`SessionMismatch` instead of silently mixing two draws.
"""
import pytest

from repro.core import MatchConfig, MiningConfig, mine
from repro.data.synthetic import rmat_graph
from repro.runtime import MiningSession, SessionMismatch, load_session

from tests.runtime.test_session import Boom, _killed_session, _norm


def _graph():
    return rmat_graph(64, 320, n_labels=2, seed=3, undirected=True)


def _cfg(**kw):
    kw.setdefault("sigma", 6)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    kw.setdefault("match", MatchConfig(cap=512, root_block=8, chunk=16,
                                       max_chunks=4, bisect_iters=7))
    kw.setdefault("execution", "sampled")
    kw.setdefault("sample_fraction", 0.5)
    return MiningConfig(metric=kw.pop("metric", "mis"), **kw)


def test_sampled_session_equals_mine(tmp_path):
    g, cfg = _graph(), _cfg()
    ref = mine(g, cfg)
    sess = MiningSession(g, cfg, tmp_path, checkpoint_every=1)
    assert _norm(sess.run()) == _norm(ref)
    # the recorded level plans carry the draw (positions + RNG key)
    plans = [lvl["plan"] for lvl in ref.per_level.values() if "plan" in lvl]
    assert any(p.get("sample") for p in plans), "no draw ever recorded"
    for p in plans:
        if p.get("sample"):
            s = p["sample"]
            assert len(s["positions"]) == s["n_sample"]
            assert s["key"][0] == cfg.sample_seed


@pytest.mark.parametrize("kw", [
    # the default: mid-level draw + escalation, mis greedy ordering
    dict(),
    # smaller fraction → more pruning/escalation churn to replay
    dict(sample_fraction=0.25, metric="mni", sigma=4, lam=0.5),
])
def test_sampled_resume_bit_identical_at_every_snapshot(tmp_path, kw):
    g = _graph()
    cfg = _cfg(**kw)
    ref = mine(g, cfg)

    base = MiningSession(g, cfg, tmp_path / "base", checkpoint_every=1,
                         keep_last=100)
    assert _norm(base.run()) == _norm(ref)
    total = base.snapshots_written
    assert total >= 2

    for kill_at in range(1, total + 1):
        d = tmp_path / f"kill{kill_at}"
        fired = _killed_session(g, cfg, d, kill_at,
                                checkpoint_every=1, keep_last=100)
        assert fired, f"bomb at snapshot {kill_at} never fired"
        resumed = MiningSession(g, cfg, d, checkpoint_every=1,
                                keep_last=100).run()
        got, want = _norm(resumed), _norm(ref)
        assert got == want, f"kill_at={kill_at}"


def test_sampled_resume_replays_draw_not_redraws(tmp_path):
    """The resumed process replays the *recorded* positions even when its
    own planner would draw differently (sample_seed pinned via snapshot:
    we tamper with nothing, just assert the per-level sample dicts of the
    resumed run equal the uninterrupted run's — a re-draw at the resumed
    level would shift the RNG chain and telemetry)."""
    g, cfg = _graph(), _cfg()
    ref = mine(g, cfg)
    fired = _killed_session(g, cfg, tmp_path, 2, checkpoint_every=1,
                            keep_last=100)
    assert fired
    assert load_session(tmp_path, cfg) is not None
    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=1,
                            keep_last=100).run()
    ref_plans = {k: v.get("plan") for k, v in ref.per_level.items()}
    got_plans = {k: v.get("plan") for k, v in resumed.per_level.items()}
    assert got_plans == ref_plans


def test_sample_fraction_mismatch_refuses_resume(tmp_path):
    g = _graph()
    MiningSession(g, _cfg(), tmp_path, checkpoint_every=0).run()
    with pytest.raises(SessionMismatch):
        MiningSession(g, _cfg(sample_fraction=0.75), tmp_path).run()
    with pytest.raises(SessionMismatch):
        MiningSession(g, _cfg(sample_seed=1), tmp_path).run()
    with pytest.raises(SessionMismatch):
        MiningSession(g, _cfg(confidence=0.9), tmp_path).run()
    # unchanged knobs resume fine (finished run re-materializes)
    again = MiningSession(g, _cfg(), tmp_path)
    again.run()
    assert again.snapshots_written == 0
