"""Auto-planned sampled levels — pricing fires for real, and survives kills.

The ISSUE 10 acceptance property: when the *auto* planner prices a level
onto the sampled plane (rather than the user forcing it), the run's
frequent set and supports stay bit-identical to the forced-batched
oracle across every batchable metric — including a kill at any snapshot
point, after which the resumed session replays the recorded pricing
decision, sample rounds, and within-level replans verbatim instead of
re-deriving them.

The cost model is pinned via a schema-3 calibration file with a high
dispatch overhead: on these tiny graphs that makes the batched row beat
sequential (amortized dispatch), which puts the sampled row on the
table; τ = 6 at ``sample_fraction = 0.5`` then clears the hidden-mass
bound (≈ 4.3) and the prior escalation mass prices the sample in.
"""
import json

import pytest

from repro.core import MatchConfig, MiningConfig, mine
from repro.core.planner import CALIBRATION_ENV
from repro.data.synthetic import rmat_graph
from repro.runtime import MiningSession

from tests.runtime.test_session import _killed_session

METRICS = ("mis", "mis_luby", "mni", "frac")

# auto-only per-level diagnostics, absent from forced-batched runs
_AUTO_KEYS = ("plan", "sampled", "block_peaks", "replans")


def _graph():
    return rmat_graph(64, 320, n_labels=2, seed=3, undirected=True)


def _cfg(metric, execution, **kw):
    kw.setdefault("sigma", 6)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    kw.setdefault("sample_fraction", 0.5)
    kw.setdefault("match", MatchConfig(cap=512, root_block=8, chunk=16,
                                       max_chunks=4, bisect_iters=7))
    return MiningConfig(metric=metric, execution=execution, **kw)


@pytest.fixture
def priced(tmp_path, monkeypatch):
    """Pin a cost model under which batched (and thus sampled) can win."""
    cal = tmp_path / "calibration.json"
    cal.write_text(json.dumps({
        "schema": 3, "dispatch_overhead_s": 0.05, "lane_time_s": 2e-9,
        "row_time_s": 4e-6, "vmap_factor": 1.15,
        "escalation_fraction": 0.25}))
    monkeypatch.setenv(CALIBRATION_ENV, str(cal))


def _oracle_norm(res):
    """Plane-invariant result view: frequent set, full stats, per-level
    counts minus wall clock, dispatch totals (sample + escalation passes
    split differently) and the auto-only diagnostics."""
    return dict(
        frequent=[(p.key(), s) for p, s in res.frequent],
        stats=[(st.pattern.key(), st.support, st.tau, st.frequent,
                st.embeddings_found, st.overflowed, st.blocks_run,
                st.max_count, st.estimated) for st in res.stats],
        searched=res.searched,
        per_level={
            lvl: {k: v for k, v in st.items()
                  if k not in ("wall_s", "dispatches") + _AUTO_KEYS}
            for lvl, st in res.per_level.items()},
        timed_out=res.timed_out,
    )


def _replay_norm(res):
    """Resume-identity view: everything except wall clock — the recorded
    pricing decision, draw, adaptive rounds, and replan counts included."""
    return dict(
        frequent=[(p.key(), s) for p, s in res.frequent],
        stats=[(st.pattern.key(), st.support, st.tau, st.frequent,
                st.embeddings_found, st.overflowed, st.blocks_run,
                st.max_count, st.estimated) for st in res.stats],
        searched=res.searched,
        per_level={k: {kk: vv for kk, vv in v.items() if kk != "wall_s"}
                   for k, v in res.per_level.items()},
        timed_out=res.timed_out,
    )


def _sampled_levels(res):
    return [lvl for lvl, st in res.per_level.items()
            if (st.get("plan") or {}).get("plane") == "sampled"]


@pytest.mark.parametrize("metric", METRICS)
def test_auto_selects_sampled_and_matches_forced_batched(priced, metric):
    g = _graph()
    res = mine(g, _cfg(metric, "auto"))
    picked = _sampled_levels(res)
    assert picked, "pricing never chose the sampled plane"
    for lvl in picked:
        pr = res.per_level[lvl]["plan"]["pricing"]
        assert pr["chosen"] == "sampled"
        assert pr["sampled_s"] < pr["margin"] * pr["batched_s"]
        assert pr["tau_min"] > pr["hidden_bound"]
        assert res.per_level[lvl]["sampled"] is not None
    ref = mine(g, _cfg(metric, "batched"))
    assert _oracle_norm(res) == _oracle_norm(ref)


@pytest.mark.parametrize("metric", METRICS)
def test_auto_sampled_resume_bit_identical_at_every_snapshot(
        priced, tmp_path, metric):
    g = _graph()
    cfg = _cfg(metric, "auto")
    ref = mine(g, cfg)
    assert _sampled_levels(ref), "pricing never chose the sampled plane"
    oracle = _oracle_norm(mine(g, _cfg(metric, "batched")))
    assert _oracle_norm(ref) == oracle

    base = MiningSession(g, cfg, tmp_path / "base", checkpoint_every=1,
                         keep_last=100)
    assert _replay_norm(base.run()) == _replay_norm(ref)
    total = base.snapshots_written
    assert total >= 2

    for kill_at in range(1, total + 1):
        d = tmp_path / f"kill{kill_at}"
        fired = _killed_session(g, cfg, d, kill_at,
                                checkpoint_every=1, keep_last=100)
        assert fired, f"bomb at snapshot {kill_at} never fired"
        resumed = MiningSession(g, cfg, d, checkpoint_every=1,
                                keep_last=100).run()
        # the full per-level record — pricing decision, draw, rounds,
        # replans — replays verbatim, and the oracle equality holds
        assert _replay_norm(resumed) == _replay_norm(ref), \
            f"kill_at={kill_at}"
        assert _oracle_norm(resumed) == oracle, f"kill_at={kill_at}"
