"""Resumable mining sessions — codec roundtrip + crash-injection resume.

The resume contract under test (ISSUE 3 acceptance): a mining run killed
at *any* snapshot point — every level boundary and every mid-pattern
block — and resumed from disk produces a `MiningResult` identical to the
uninterrupted oracle in every field except wall clock (``elapsed_s``,
per-level ``wall_s``); and a crash *during* a save never corrupts the
last committed snapshot.

Graphs are deliberately tiny (the contract is structural, not scale-
dependent) so the kill-at-every-snapshot sweeps stay inside CI budget.
"""
import pytest
from hypothesis import given, settings, HealthCheck

from repro.core import MatchConfig, MiningConfig, mine
from repro.core.flexis import MiningLoopState, PatternStats
from repro.data.synthetic import rmat_graph
from repro.runtime import (
    MiningSession, SessionMismatch, decode_session, encode_session,
    load_session, SessionState,
)
from repro.train import checkpoint as ckpt
from tests.conftest import patterns


class Boom(Exception):
    """Stands in for SIGKILL: aborts the session driver mid-run."""


def _graph():
    return rmat_graph(64, 320, n_labels=2, seed=3, undirected=True)


def _match_cfg():
    return MatchConfig(cap=512, root_block=16, chunk=16, max_chunks=4,
                       bisect_iters=7)


def _cfg(metric="mis", **kw):
    kw.setdefault("sigma", 6)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    kw.setdefault("match", _match_cfg())
    return MiningConfig(metric=metric, **kw)


def _norm(res):
    """Everything in a MiningResult except wall-clock fields."""
    return dict(
        frequent=[(p.key(), s) for p, s in res.frequent],
        searched=res.searched,
        stats=[(st.pattern.key(), st.support, st.tau, st.frequent,
                st.embeddings_found, st.overflowed, st.blocks_run)
               for st in res.stats],
        per_level={k: {kk: vv for kk, vv in v.items() if kk != "wall_s"}
                   for k, v in res.per_level.items()},
        timed_out=res.timed_out,
        peak=res.peak_device_bytes,
    )


def _killed_session(g, cfg, ckpt_dir, kill_at, **kw):
    """Run a session that dies right after its kill_at-th snapshot.

    Returns True if the bomb fired (False: the run finished first).
    """
    sess = MiningSession(g, cfg, ckpt_dir, **kw)
    orig, count = sess._save, [0]

    def bomb(state):
        orig(state)
        count[0] += 1
        if count[0] >= kill_at:
            raise Boom()

    sess._save = bomb
    try:
        sess.run()
        return False
    except Boom:
        return True


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(patterns(min_k=2, max_k=4), patterns(min_k=2, max_k=4))
def test_codec_roundtrip(p1, p2):
    loop = MiningLoopState(
        level=2, cp=[p1, p2], frequent=[(p1, 7)],
        stats=[PatternStats(pattern=p2, support=3, tau=2, frequent=True,
                            embeddings_found=11, overflowed=False,
                            blocks_run=4)],
        per_level={1: {"candidates": 2, "searched": 2, "pruned": 0,
                       "frequent": 1, "dispatches": 3, "wall_s": 0.25}},
        searched=2, peak_bytes=1234, elapsed_s=1.5, timed_out=False)
    state = SessionState(loop=loop)
    leaves, extra = encode_session(state, "mis")
    import json
    extra = json.loads(json.dumps(extra))  # what the manifest does
    back = decode_session(leaves, extra, "mis")
    assert back.cursor is None
    assert [p.key() for p in back.loop.cp] == [p1.key(), p2.key()]
    assert [(p.key(), s) for p, s in back.loop.frequent] == [(p1.key(), 7)]
    assert back.loop.per_level == loop.per_level
    assert back.loop.stats[0].pattern.key() == p2.key()
    assert back.loop.stats[0].support == 3
    assert (back.loop.level, back.loop.searched, back.loop.peak_bytes,
            back.loop.elapsed_s) == (2, 2, 1234, 1.5)


# ---------------------------------------------------------------------------
# sessions ≡ mine(), fresh and finished
# ---------------------------------------------------------------------------

def test_session_equals_mine_and_finished_resume(tmp_path):
    g, cfg = _graph(), _cfg("mis")
    ref = mine(g, cfg)
    sess = MiningSession(g, cfg, tmp_path, checkpoint_every=1)
    assert _norm(sess.run()) == _norm(ref)
    assert sess.snapshots_written >= 1
    # resuming a *finished* session re-materializes the result without
    # re-mining (the final snapshot carries an empty candidate list)
    again = MiningSession(g, cfg, tmp_path)
    assert _norm(again.run()) == _norm(ref)
    assert again.snapshots_written == 0


def test_resume_modes(tmp_path):
    g, cfg = _graph(), _cfg("mis")
    with pytest.raises(FileNotFoundError):
        MiningSession(g, cfg, tmp_path / "empty", resume="must").run()
    MiningSession(g, cfg, tmp_path, checkpoint_every=0).run()
    assert load_session(tmp_path, cfg) is not None


def test_fingerprint_mismatch_refuses_resume(tmp_path):
    g = _graph()
    MiningSession(g, _cfg("mis"), tmp_path, checkpoint_every=0).run()
    with pytest.raises(SessionMismatch):
        MiningSession(g, _cfg("mis", sigma=7), tmp_path).run()
    g2 = rmat_graph(64, 320, n_labels=2, seed=4, undirected=True)
    with pytest.raises(SessionMismatch):
        MiningSession(g2, _cfg("mis"), tmp_path).run()


# ---------------------------------------------------------------------------
# crash-injection property: kill at EVERY snapshot point, resume, compare
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric,kw", [
    # complete=True maximizes block count → most mid-pattern snapshots
    # (plane pinned: on this tiny config the auto planner legitimately
    # picks sequential, which only snapshots at level boundaries)
    ("mis", dict(complete=True, execution="batched")),
    # the default auto plane: planner decisions recorded + replayed
    ("mis", dict(complete=True)),
    # early exit exercises the active-set shrink/re-stack snapshots
    ("mis_luby", dict(sigma=3, lam=0.5, execution="batched")),
    ("mni", dict(sigma=3, lam=0.5, execution="batched")),
    ("frac", dict(sigma=2, lam=0.5, execution="batched")),
    # sequential plane: level-boundary snapshots only
    ("mis", dict(sigma=3, lam=0.5, execution="sequential")),
])
def test_resume_bit_identical_at_every_snapshot(tmp_path, metric, kw):
    g = _graph()
    cfg = _cfg(metric, **kw)
    ref = mine(g, cfg)

    base = MiningSession(g, cfg, tmp_path / "base", checkpoint_every=1,
                         keep_last=100)
    assert _norm(base.run()) == _norm(ref)
    total = base.snapshots_written
    assert total >= 2  # at least one level boundary + the final snapshot

    for kill_at in range(1, total + 1):
        d = tmp_path / f"kill{kill_at}"
        fired = _killed_session(g, cfg, d, kill_at,
                                checkpoint_every=1, keep_last=100)
        assert fired, f"bomb at snapshot {kill_at} never fired"
        resumed = MiningSession(g, cfg, d, checkpoint_every=1,
                                keep_last=100).run()
        assert _norm(resumed) == _norm(ref), f"kill_at={kill_at}"


def test_resume_survives_crash_during_save(tmp_path, monkeypatch):
    """A kill *inside* the checkpoint write (tmp written, COMMIT not) must
    fall back to the previous committed snapshot and still converge.
    (Plane pinned to batched: the crash is injected at the 3rd snapshot,
    which needs the mid-pattern snapshot cadence.)"""
    g, cfg = _graph(), _cfg("mis", complete=True, execution="batched")
    ref = mine(g, cfg)

    sess = MiningSession(g, cfg, tmp_path, checkpoint_every=1, keep_last=100)
    count = [0]
    real_save = ckpt.save

    def crashing_save(root, step, tree, **kwargs):
        count[0] += 1
        if count[0] == 3:  # third snapshot: die mid-write
            from pathlib import Path
            tmp = Path(root) / f"step_{step:08d}.tmp"
            tmp.mkdir(parents=True, exist_ok=True)
            (tmp / "manifest.json").write_text("{\"half\": true}")
            raise Boom()
        return real_save(root, step, tree, **kwargs)

    monkeypatch.setattr("repro.runtime.session.ckpt.save", crashing_save)
    with pytest.raises(Boom):
        sess.run()
    monkeypatch.undo()

    assert ckpt.latest_step(tmp_path) is not None
    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=1,
                            keep_last=100).run()
    assert _norm(resumed) == _norm(ref)


def test_resume_pins_planner_calibration(tmp_path, monkeypatch):
    """A resumed session must replan with the cost model the run STARTED
    with, even if the calibration file changed between processes — the
    planner decisions (and with them the whole per_level record) stay
    bit-identical.  Also checks the in-flight level's plan is snapshotted
    and replayed verbatim."""
    import json

    from repro.core.planner import CALIBRATION_ENV

    g, cfg = _graph(), _cfg("mis", complete=True)
    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    ref = mine(g, cfg)

    # kill right after the level-1 boundary snapshot, so the resumed
    # process must *replan* level 2 itself
    fired = _killed_session(g, cfg, tmp_path, 1, checkpoint_every=1,
                            keep_last=100)
    assert fired
    # between the kill and the resume, the world learns absurd
    # overhead-dominated constants under which EVERY multi-pattern level
    # would flip to the batched plane on a fresh run …
    crazy = tmp_path / "crazy_calibration.json"
    crazy.write_text(json.dumps({
        "schema": 1, "dispatch_overhead_s": 100.0,
        "lane_time_s": 1e-15, "row_time_s": 1e-15, "vmap_factor": 1.0}))
    monkeypatch.setenv(CALIBRATION_ENV, str(crazy))
    fresh = mine(g, cfg)
    assert any(st["plan"]["plane"] == "batched"
               for st in fresh.per_level.values())
    # … but the resumed session replans with the PINNED constants and
    # reproduces the original run bit-identically, plan records included
    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=1,
                            keep_last=100).run()
    assert _norm(resumed) == _norm(ref)


def test_coarse_checkpoint_cadence(tmp_path):
    """checkpoint_every > 1 loses at most that many blocks, never
    correctness."""
    g, cfg = _graph(), _cfg("mis", complete=True)
    ref = mine(g, cfg)
    fired = _killed_session(g, cfg, tmp_path, 2, checkpoint_every=3,
                            keep_last=100)
    assert fired
    resumed = MiningSession(g, cfg, tmp_path, checkpoint_every=3,
                            keep_last=100).run()
    assert _norm(resumed) == _norm(ref)
