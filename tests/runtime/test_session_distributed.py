"""Cross-mesh session resume — subprocess runs with forced host devices.

The elastic acceptance story: a distributed (shard_map) mining session
killed mid-pattern on a 4-device mesh resumes on 1 or 8 devices and
produces the same `MiningResult` — supports, stats, per-level counts —
because the logical super-block schedule (`MiningConfig.blocks_per_super`)
is pinned by the session and the carried mIS state is saved as full
logical arrays.  Only ``wall_s`` and ``dispatches`` are excluded from the
comparison: dispatch count is the number of actual `shard_map` launches,
which is a property of the mesh, not of the mined result (3 blocks are 3
launches on 1 device but 1 launch on 4).

XLA_FLAGS must be set before jax initializes, hence subprocesses.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_DRIVER = textwrap.dedent("""
    import json, os, sys
    ndev, ckpt_dir, mode, out = sys.argv[1:5]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ndev}")
    import numpy as np, jax
    assert len(jax.devices()) == int(ndev)
    from repro.core import MatchConfig, MiningConfig
    from repro.data.synthetic import rmat_graph
    from repro.runtime import MiningSession

    g = rmat_graph(100, 600, n_labels=2, seed=3, undirected=True)
    cfg = MiningConfig(sigma=3, lam=0.5, metric="mis_luby",
                       max_pattern_size=3, execution="distributed",
                       blocks_per_super=3,
                       match=MatchConfig(cap=1024, root_block=16, chunk=16,
                                         max_chunks=6, bisect_iters=8))

    class Boom(Exception):
        pass

    sess = MiningSession(g, cfg, ckpt_dir, checkpoint_every=1, keep_last=100)
    if mode.startswith("kill:"):
        kill_at = int(mode.split(":")[1])
        orig, count = sess._save, [0]
        def bomb(state):
            orig(state)
            count[0] += 1
            if count[0] >= kill_at:
                raise Boom()
        sess._save = bomb
    try:
        res = sess.run()
    except Boom:
        print("KILLED", flush=True)
        sys.exit(0)
    json.dump({
        "frequent": [[p.labels.tolist(), p.edges(), int(s)]
                     for p, s in res.frequent],
        "searched": res.searched,
        "stats": [[st.pattern.labels.tolist(), st.pattern.edges(),
                   st.support, st.tau, st.frequent, st.embeddings_found,
                   st.overflowed, st.blocks_run] for st in res.stats],
        "per_level": {str(k): {kk: vv for kk, vv in v.items()
                               if kk not in ("wall_s", "dispatches")}
                      for k, v in res.per_level.items()},
        "timed_out": res.timed_out,
    }, open(out, "w"), sort_keys=True)
    print("DONE", flush=True)
""")


def _run(ndev, ckpt_dir, mode, out):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _DRIVER, str(ndev), str(ckpt_dir), mode,
         str(out)],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_mid_super_block_resume_across_mesh_shapes(tmp_path):
    oracle_json = tmp_path / "oracle.json"
    out = _run(4, tmp_path / "oracle_ck", "full", oracle_json)
    assert "DONE" in out
    oracle = json.loads(oracle_json.read_text())
    assert oracle["searched"] > 0

    # kill the 4-device run right after its 2nd snapshot (mid-level,
    # mid-pattern: level 2 runs several super-blocks) …
    for resume_ndev in (1, 4, 8):
        ck = tmp_path / f"ck_nd{resume_ndev}"
        out = _run(4, ck, "kill:2", tmp_path / "killed.json")
        assert "KILLED" in out
        # … and resume it on a smaller, equal and larger mesh
        res_json = tmp_path / f"res_nd{resume_ndev}.json"
        out = _run(resume_ndev, ck, "resume", res_json)
        assert "DONE" in out
        got = json.loads(res_json.read_text())
        assert got == oracle, f"resume on {resume_ndev} devices diverged"
