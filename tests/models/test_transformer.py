"""Transformer consistency: decode-vs-forward equivalence, chunked
attention, remat invariance, MoE exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (
    TransformerConfig, init_decode_cache, lm_loss, transformer_apply,
    transformer_decode, transformer_init,
)

TINY = TransformerConfig(
    name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=8, d_ff=64, remat=False)


def _toks(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)


@pytest.mark.parametrize("variant", ["dense", "gemma2ish", "moe", "window"])
def test_decode_matches_forward(variant):
    """Token-by-token decode with KV cache == full forward logits."""
    cfg = {
        "dense": TINY,
        "gemma2ish": dataclasses.replace(
            TINY, local_global=True, window=6, n_layers=4,
            attn_softcap=50.0, final_softcap=30.0),
        # high capacity factor: no token drops, so decode == forward exactly
        "moe": dataclasses.replace(TINY, d_ff=0, n_experts=4, top_k=2,
                                   moe_d_ff=32, moe_capacity_factor=8.0),
        "window": dataclasses.replace(TINY, window=5),
    }[variant]
    params = transformer_init(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = _toks(cfg, B, S)
    full_logits, _ = transformer_apply(params, cfg, toks)

    cache = init_decode_cache(cfg, B, S)
    got = []
    for i in range(S):
        logits, cache = transformer_decode(
            params, cfg, cache, toks[:, i:i + 1],
            jnp.full((B,), i, jnp.int32))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(full_logits, np.float32),
        atol=0.15, rtol=0.1)  # bf16 accumulation differences


def test_chunked_equals_dense_end_to_end():
    # f32 compute so the only difference is the attention algorithm itself
    cfg_d = dataclasses.replace(TINY, attn_impl="dense", dtype=jnp.float32)
    cfg_c = dataclasses.replace(TINY, attn_impl="chunked", q_chunk=4,
                                kv_chunk=4, dtype=jnp.float32)
    params = transformer_init(jax.random.key(1), cfg_d)
    toks = _toks(cfg_d, 2, 16)
    ld = np.asarray(transformer_apply(params, cfg_d, toks)[0], np.float32)
    lc = np.asarray(transformer_apply(params, cfg_c, toks)[0], np.float32)
    # layers still run bf16 projections; compare relative to logit scale
    assert np.abs(ld - lc).max() <= 0.02 * np.abs(ld).max() + 0.05


def test_remat_invariance():
    cfg_r = dataclasses.replace(TINY, remat=True)
    params = transformer_init(jax.random.key(2), TINY)
    toks = _toks(TINY, 2, 8)
    g1 = jax.grad(lm_loss)(params, TINY, toks, toks)
    g2 = jax.grad(lm_loss)(params, cfg_r, toks, toks)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4), g1, g2)


def test_loss_decreases_under_training():
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = TINY
    params = transformer_init(jax.random.key(3), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40,
                          weight_decay=0.0)
    opt = adamw_init(params)
    toks = _toks(cfg, 4, 16, seed=9)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, toks, toks)
        params, opt = adamw_update(opt_cfg, grads, opt, params)
        return loss, params, opt

    losses = []
    for _ in range(30):
        loss, params, opt = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_param_count_analytic_matches_actual():
    from repro.models.common import count_params

    for cfg in (TINY,
                dataclasses.replace(TINY, d_ff=0, n_experts=4, top_k=2,
                                    moe_d_ff=32)):
        params = transformer_init(jax.random.key(0), cfg)
        actual = count_params(params)
        # analytic: embeddings + layers + final norm (±norm scales)
        assert abs(actual - cfg.param_count()) / actual < 0.05
