"""GNN model properties: equivariance, permutation invariance, cutoffs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn.common import GraphBatch
from repro.models.gnn import nequip as nq
from repro.models.gnn.graphsage import SAGEConfig, sage_apply, sage_init
from repro.models.gnn.schnet import SchNetConfig, schnet_apply, schnet_init


def _batch(rng, N=12, E=30, F=6, n_graphs=1, pos=True):
    return GraphBatch(
        x=jnp.asarray(rng.normal(size=(N, F)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_mask=jnp.ones((E,), bool),
        node_mask=jnp.ones((N,), bool),
        graph_ids=jnp.zeros((N,), jnp.int32),
        n_graphs=n_graphs,
        targets=jnp.zeros((n_graphs,), jnp.float32),
        pos=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32) if pos else None,
    )


def _rotation(rng):
    A = rng.normal(size=(3, 3))
    Q, R = np.linalg.qr(A)
    Q = Q * np.sign(np.diag(R))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return jnp.asarray(Q, jnp.float32)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_nequip_e3_invariance(seed):
    """Predicted energy is invariant under global rotation + translation."""
    rng = np.random.default_rng(seed)
    gb = _batch(rng)
    cfg = nq.NequIPConfig(d_in=6, d_hidden=8, n_layers=3)
    params = nq.nequip_init(jax.random.key(seed), cfg)
    e1 = nq.nequip_apply(params, cfg, gb)
    Q = _rotation(rng)
    t = jnp.asarray(rng.normal(size=(1, 3)), jnp.float32)
    gb2 = GraphBatch(**{**gb.__dict__, "pos": gb.pos @ Q.T + t})
    e2 = nq.nequip_apply(params, cfg, gb2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=2e-4,
                               rtol=2e-4)


def test_nequip_sensitive_to_geometry():
    """…but NOT invariant to non-rigid distortion (the features are real)."""
    rng = np.random.default_rng(3)
    gb = _batch(rng)
    cfg = nq.NequIPConfig(d_in=6, d_hidden=8, n_layers=2)
    params = nq.nequip_init(jax.random.key(0), cfg)
    e1 = nq.nequip_apply(params, cfg, gb)
    gb2 = GraphBatch(**{**gb.__dict__,
                        "pos": gb.pos * jnp.asarray([2.0, 1.0, 0.5])})
    e2 = nq.nequip_apply(params, cfg, gb2)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_sage_permutation_equivariance():
    """Node relabeling permutes SAGE outputs identically."""
    rng = np.random.default_rng(4)
    N, E, F = 10, 24, 5
    gb = _batch(rng, N=N, E=E, F=F, pos=False)
    cfg = SAGEConfig(d_in=F, d_hidden=16, n_classes=3)
    params = sage_init(jax.random.key(1), cfg)
    out1 = sage_apply(params, cfg, gb)

    perm = rng.permutation(N)
    inv = np.argsort(perm)
    gb2 = GraphBatch(**{**gb.__dict__,
                        "x": gb.x[jnp.asarray(inv)],
                        "edge_src": jnp.asarray(perm)[gb.edge_src],
                        "edge_dst": jnp.asarray(perm)[gb.edge_dst]})
    out2 = sage_apply(params, cfg, gb2)
    # old node i sits at new position perm[i] ⇒ out2[perm[i]] == out1[i]
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2)[perm],
                               atol=2e-2, rtol=2e-2)


def test_schnet_cutoff_kills_long_edges():
    """Edges beyond the cutoff contribute (numerically) nothing."""
    rng = np.random.default_rng(5)
    N = 6
    pos = np.zeros((N, 3), np.float32)
    pos[3:] += 100.0  # far cluster
    gb = GraphBatch(
        x=jnp.asarray(rng.normal(size=(N, 4)), jnp.float32),
        edge_src=jnp.asarray([0, 1, 3, 0], jnp.int32),
        edge_dst=jnp.asarray([1, 2, 4, 3], jnp.int32),  # 0→3 spans clusters
        edge_mask=jnp.ones((4,), bool),
        node_mask=jnp.ones((N,), bool),
        graph_ids=jnp.zeros((N,), jnp.int32), n_graphs=1,
        targets=jnp.zeros((1,), jnp.float32),
        pos=jnp.asarray(pos))
    cfg = SchNetConfig(d_in=4, d_hidden=8, n_rbf=16, cutoff=5.0,
                       graph_level=False, n_out=2)
    params = schnet_init(jax.random.key(0), cfg)
    out1 = schnet_apply(params, cfg, gb)
    # removing the cross-cluster edge changes nothing (cutoff envelope = 0)
    gb2 = GraphBatch(**{**gb.__dict__,
                        "edge_mask": jnp.asarray([True, True, True, False])})
    out2 = schnet_apply(params, cfg, gb2)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out2, np.float32), atol=1e-3)
