"""Per-arch smoke tests (deliverable f): reduced config, one real step on
CPU, asserting output shapes + no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_arch, list_archs
from repro.train.optimizer import adamw_init


def _cells():
    out = []
    for name in list_archs():
        arch = get_arch(name)
        for shape in arch.shapes():
            out.append((name, shape))
    return out


@pytest.mark.parametrize("name,shape", _cells())
def test_arch_shape_smoke(name, shape):
    arch = get_arch(name)
    skip = arch.skip_reason(shape)
    if skip:
        pytest.skip(skip)
    step = arch.reduced_step_fn(shape)
    inputs = arch.reduced_inputs(shape, jax.random.key(0))
    kind = arch.shapes()[shape].kind

    if arch.family == "gnn":
        params = arch.init_reduced(jax.random.key(1), shape)
    else:
        params = arch.init_reduced(jax.random.key(1))

    if kind == "train":
        opt = adamw_init(params)
        loss, new_params, new_opt = step(params, opt, **inputs)
        assert np.isfinite(float(loss)), f"{name}/{shape}: loss not finite"
        # params actually changed
        l0 = jax.tree_util.tree_leaves(params)[0]
        l1 = jax.tree_util.tree_leaves(new_params)[0]
        assert l0.shape == l1.shape
        assert int(new_opt.step) == 1
    elif kind == "prefill":
        out = step(params, **inputs)
        B = inputs["tokens"].shape[0]
        assert out.shape[0] == B
        assert np.isfinite(np.asarray(out, np.float32)).all()
    elif kind == "decode":
        logits, cache = step(params, **inputs)
        assert logits.shape[0] == inputs["tokens"].shape[0]
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # cache must keep its structure & shapes
        s0 = jax.tree_util.tree_map(lambda x: x.shape, inputs["cache"])
        s1 = jax.tree_util.tree_map(lambda x: x.shape, cache)
        assert s0 == s1
    elif kind == "retrieval":
        scores, ids = step(params, **inputs)
        assert scores.shape == ids.shape
        assert np.isfinite(np.asarray(scores, np.float32)).all()
    else:  # serve
        out = step(params, **inputs)
        assert np.isfinite(np.asarray(out, np.float32)).all()


def test_registry_covers_40_cells():
    from repro.configs.registry import all_cells

    cells = all_cells()
    assert len(cells) == 40  # (5 LM + 4 GNN + 1 recsys) × 4 shapes
    lm_cells = [c for c in cells if get_arch(c[0]).family == "lm"]
    assert len(lm_cells) == 20
    skips = [c for c in cells if c[2] is not None]
    # documented skips: long_500k on the three pure full-attention stacks
    assert {(c[0], c[1]) for c in skips} == {
        ("minitron-4b", "long_500k"),
        ("qwen3-1.7b", "long_500k"),
        ("qwen3-moe-30b-a3b", "long_500k"),
    }
