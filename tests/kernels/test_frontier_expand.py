"""Fused frontier-expansion kernel: parity vs ref vs the XLA pipeline.

Three implementations must agree bit-for-bit (single-phase semantics):
  * the Pallas kernel (interpret mode on this CPU container),
  * `ref.frontier_expand_ref` (the single-phase XLA pipeline),
  * `match_block` with expansion="xla", two_phase=False.
Coverage includes edgeless graphs, cap-overflow truncation, the two-phase
no-overflow equivalence, and the batched pattern axis (vmap ⇒ kernel grid).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import MatchConfig, Pattern, build_graph
from repro.core.flexis import initial_candidates
from repro.core.generation import generate_new_patterns
from repro.core.graph import DeviceGraph
from repro.core.matcher import match_block
from repro.core.plan import make_plan, stack_plans
from repro.kernels.frontier_expand.ops import frontier_expand_level
from repro.kernels.frontier_expand.ref import frontier_expand_ref


def _random_graph(n, deg, n_labels, seed, undirected=True):
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    labels = rng.integers(0, n_labels, n)
    return build_graph(n, np.stack([src, dst], 1), labels,
                       undirected=undirected)


def _xla_cfg(g, cap=256, root_block=128):
    cfg = MatchConfig.for_graph(g, cap=cap, root_block=root_block)
    return dataclasses.replace(cfg, two_phase=False)


def _pallas_cfg(cfg):
    return dataclasses.replace(cfg, expansion="pallas")


def _some_plans(g, want=6):
    pats = initial_candidates(g)
    plans = [make_plan(p, g) for p in pats[:want]]
    for p in generate_new_patterns(pats[: min(len(pats), 6)])[:want]:
        plans.append(make_plan(p, g))
    return plans


def _assert_block_equal(a, b):
    # frontier_expand_level returns 4 fields; match_block appends peak
    ea, ca, fa, oa, *rest_a = a
    eb, cb, fb, ob, *rest_b = b
    assert int(ca) == int(cb)
    assert int(fa) == int(fb)
    assert bool(oa) == bool(ob)
    np.testing.assert_array_equal(np.asarray(ea), np.asarray(eb))
    for ra, rb in zip(rest_a, rest_b):
        assert int(ra) == int(rb)


# ---------------------------------------------------------------------------
# whole-block parity: pallas == xla(single-phase) on directed + undirected
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("undirected", [True, False])
def test_match_block_parity(undirected):
    g = _random_graph(200, 3, 4, seed=1, undirected=undirected)
    dev = DeviceGraph.from_host(g)
    cfg = _xla_cfg(g)
    for plan in _some_plans(g):
        for bs in (0, cfg.root_block):
            _assert_block_equal(
                match_block(dev, plan, jnp.int32(bs), cfg),
                match_block(dev, plan, jnp.int32(bs), _pallas_cfg(cfg)))


# ---------------------------------------------------------------------------
# per-level parity: kernel vs ref on hand-built frontier states
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(16, 80), st.integers(1, 4),
       st.integers(2, 4))
def test_level_parity_property(seed, n, deg, n_labels):
    g = _random_graph(n, deg, n_labels, seed=seed)
    dev = DeviceGraph.from_host(g)
    cfg = _xla_cfg(g, cap=64, root_block=64)
    for plan in _some_plans(g, want=3):
        emb, count, *_ = match_block(dev, plan, jnp.int32(0), cfg)
        if plan.k < 3:
            continue
        # re-run the last level in isolation through both planes
        base = jnp.concatenate(
            [emb[:, : plan.k - 1],
             jnp.full((cfg.cap, 1), -1, jnp.int32)], axis=1)
        got = frontier_expand_level(dev, plan, base, count, plan.k - 1, cfg)
        ref = frontier_expand_ref(dev, plan, base, count, plan.k - 1, cfg)
        _assert_block_equal(got, ref)


# ---------------------------------------------------------------------------
# edgeless graphs: sentinel index arrays must stay well-formed in-kernel
# ---------------------------------------------------------------------------

def test_edgeless_graph():
    n = 32
    g = build_graph(n, np.zeros((0, 2), np.int64), np.zeros(n, np.int32))
    dev = DeviceGraph.from_host(g)
    cfg = _xla_cfg(g, cap=64, root_block=32)
    pat = Pattern(np.array([[False, True], [False, False]]),
                  np.zeros(2, np.int32))
    plan = make_plan(pat, g)
    got = match_block(dev, plan, jnp.int32(0), _pallas_cfg(cfg))
    ref = match_block(dev, plan, jnp.int32(0), cfg)
    _assert_block_equal(got, ref)
    assert int(got[1]) == 0 and int(got[2]) == 0


# ---------------------------------------------------------------------------
# cap overflow: identical truncation (content, count, found, flag)
# ---------------------------------------------------------------------------

def test_cap_overflow_truncation():
    # dense same-label graph + tiny cap forces every level past capacity
    g = _random_graph(64, 8, 1, seed=3)
    cfg = dataclasses.replace(
        MatchConfig.for_graph(g, cap=8192, root_block=64),
        cap=16, two_phase=False)
    dev = DeviceGraph.from_host(g)
    plans = _some_plans(g, want=4)
    overflowed_any = False
    for plan in plans:
        got = match_block(dev, plan, jnp.int32(0), _pallas_cfg(cfg))
        ref = match_block(dev, plan, jnp.int32(0), cfg)
        _assert_block_equal(got, ref)
        overflowed_any |= bool(got[3])
    assert overflowed_any, "geometry was meant to overflow"


# ---------------------------------------------------------------------------
# two-phase xla path: same results when nothing overflows
# ---------------------------------------------------------------------------

def test_two_phase_equivalence_no_overflow():
    g = _random_graph(150, 2, 5, seed=4)
    dev = DeviceGraph.from_host(g)
    cfg1 = _xla_cfg(g)                                        # single-phase
    cfg2 = dataclasses.replace(cfg1, two_phase=True)
    cfgp = _pallas_cfg(cfg1)
    for plan in _some_plans(g):
        ref = match_block(dev, plan, jnp.int32(0), cfg2)
        if bool(ref[3]):
            continue  # phase-1 overflow may reorder truncation; skip
        _assert_block_equal(match_block(dev, plan, jnp.int32(0), cfgp), ref)


# ---------------------------------------------------------------------------
# batched pattern axis: vmap turns into one kernel launch per level
# ---------------------------------------------------------------------------

def test_batched_pattern_axis_parity():
    g = _random_graph(200, 3, 3, seed=5)
    dev = DeviceGraph.from_host(g)
    cfg = _xla_cfg(g)
    pats = initial_candidates(g)
    k3 = generate_new_patterns(pats[: min(len(pats), 8)])[:4]
    assert len(k3) >= 2
    plans = stack_plans([make_plan(p, g) for p in k3])

    def run(c):
        return jax.vmap(
            lambda p: match_block(dev, p, jnp.int32(0), c))(plans)

    for a, b in zip(run(cfg), run(_pallas_cfg(cfg))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_plane_end_to_end():
    """evaluate_level_batched with the pallas plane == sequential oracle."""
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import MiningConfig, evaluate_pattern

    g = _random_graph(120, 2, 3, seed=6)
    dev = DeviceGraph.from_host(g)
    cfg = _pallas_cfg(_xla_cfg(g, cap=64, root_block=64))
    cands = initial_candidates(g)[:6]
    taus = [2] * len(cands)
    out, timed_out, _ = evaluate_level_batched(
        g, dev, cands, taus, "mis", cfg, complete=True)
    assert not timed_out
    seq_cfg = MiningConfig(sigma=2, lam=1.0, metric="mis", complete=True,
                           match=cfg, execution="sequential")
    for pat, tau, o in zip(cands, taus, out):
        st_ = evaluate_pattern(g, dev, pat, tau, seq_cfg)
        assert (o.support, o.embeddings_found, o.overflowed) == \
            (st_.support, st_.embeddings_found, st_.overflowed)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_expansion_validation():
    with pytest.raises(ValueError):
        MatchConfig(expansion="fused")
    assert MatchConfig(expansion="pallas").expansion == "pallas"


def test_two_phase_normalized_off_on_pallas_plane():
    """two_phase is an xla-plane knob; a pallas config must not claim it."""
    cfg = MatchConfig(expansion="pallas", two_phase=True)
    assert cfg.two_phase is False
    assert MatchConfig(expansion="xla", two_phase=True).two_phase is True


def test_vmem_guard_rejects_oversized_hardware_geometry():
    from repro.kernels.frontier_expand.kernel import (
        frontier_expand, frontier_expand_vmem_bytes)

    g = _random_graph(64, 2, 2, seed=7)
    dev = DeviceGraph.from_host(g)
    plan = make_plan(initial_candidates(g)[0], g)
    cap = 1 << 20  # ~8 GiB of candidate rows: must be refused pre-Mosaic
    assert frontier_expand_vmem_bytes(g.n, 2 * g.n_edges, cap, 64,
                                      plan.k) > 16 * 2**20
    emb = jnp.full((cap, plan.k), -1, jnp.int32)
    with pytest.raises(ValueError, match="VMEM"):
        frontier_expand(
            dev.labels, dev.out_indptr, dev.out_indices, dev.in_indptr,
            dev.in_indices, emb, jnp.int32(0), plan.anchor_pos[1],
            plan.anchor_out[1], plan.cand_label[1], plan.min_out[1],
            plan.min_in[1], plan.check_out[1], plan.check_in[1],
            level=1, k=plan.k, cap=cap, chunk=64, max_chunks=1,
            bisect_iters=4, n=g.n, interpret=False)
