"""Per-kernel sweeps: shapes × dtypes, interpret-mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mis import bitmap_init
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mis_bitmap.ops import mis_greedy_update_kernel
from repro.kernels.mis_bitmap.ref import mis_bitmap_ref
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.gather_aggregate.kernel import gather_aggregate_pallas
from repro.kernels.gather_aggregate.ref import gather_aggregate_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 2, 2, 16),
    (2, 128, 4, 2, 32),
    (1, 256, 8, 4, 16),
    (2, 64, 4, 1, 64),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, KV, hd, dtype):
    rng = np.random.default_rng(hash((B, S, H, KV, hd)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    got = flash_attention(q, k, v, window=window, softcap=softcap,
                          bq=32, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_block_size_invariance():
    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 1, 128, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    outs = [flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
            for bq, bk in ((32, 32), (64, 128), (128, 64))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# mIS bitmap
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(33, 400), st.integers(2, 5), st.integers(0, 63),
       st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_mis_bitmap_matches_ref(n, k, n_valid, tau, seed):
    rng = np.random.default_rng(seed)
    cap = 64
    emb = np.stack([rng.choice(n, size=k, replace=False)
                    for _ in range(cap)]).astype(np.int32)
    bm0, c0 = bitmap_init(n), jnp.int32(0)
    got_bm, got_c = mis_greedy_update_kernel(
        bm0, c0, jnp.asarray(emb), jnp.int32(n_valid), jnp.int32(tau), k)
    ref_bm, ref_c = mis_bitmap_ref(
        bm0, c0, jnp.asarray(emb), jnp.int32(n_valid), jnp.int32(tau), k)
    assert int(got_c) == int(ref_c)
    np.testing.assert_array_equal(np.asarray(got_bm), np.asarray(ref_bm))


def test_mis_bitmap_carries_state():
    n, k, cap = 100, 3, 32
    rng = np.random.default_rng(7)
    emb1 = np.stack([rng.choice(n, k, replace=False) for _ in range(cap)]).astype(np.int32)
    emb2 = np.stack([rng.choice(n, k, replace=False) for _ in range(cap)]).astype(np.int32)
    bm, c = bitmap_init(n), jnp.int32(0)
    for emb in (emb1, emb2):
        bm, c = mis_greedy_update_kernel(bm, c, jnp.asarray(emb),
                                         jnp.int32(cap), jnp.int32(1000), k)
    bm_ref, c_ref = bitmap_init(n), jnp.int32(0)
    for emb in (emb1, emb2):
        bm_ref, c_ref = mis_bitmap_ref(bm_ref, c_ref, jnp.asarray(emb),
                                       jnp.int32(cap), jnp.int32(1000), k)
    assert int(c) == int(c_ref)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bm_ref))


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,D,B,H", [(100, 32, 16, 1), (64, 16, 8, 4),
                                     (32, 128, 16, 2), (16, 8, 64, 8)])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(R, D, B, H, combiner):
    rng = np.random.default_rng(R * D + B)
    table = jnp.asarray(rng.normal(size=(R, D)), jnp.float32)
    idx = rng.integers(-1, R, (B, H)).astype(np.int32)
    got = embedding_bag_pallas(table, jnp.asarray(idx), combiner=combiner,
                               bags_per_block=8, interpret=True)
    ref = embedding_bag_ref(table, jnp.asarray(idx), combiner=combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# gather aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,F,Dmax", [(64, 16, 5), (128, 32, 8), (32, 8, 1),
                                      (256, 64, 16)])
@pytest.mark.parametrize("mean", [False, True])
def test_gather_aggregate_sweep(N, F, Dmax, mean):
    rng = np.random.default_rng(N + F)
    feats = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
    nbrs = rng.integers(-1, N, (N, Dmax)).astype(np.int32)
    got = gather_aggregate_pallas(feats, jnp.asarray(nbrs), mean=mean,
                                  block_nodes=32, interpret=True)
    ref = gather_aggregate_ref(feats, jnp.asarray(nbrs), mean=mean)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_gather_aggregate_matches_segment_sum_path():
    """Kernel result == the production segment_sum message passing."""
    from repro.models.gnn.common import scatter_sum
    from repro.kernels.gather_aggregate.ops import pad_adjacency
    from repro.core import build_graph

    rng = np.random.default_rng(3)
    n = 64
    m = rng.random((n, n)) < 0.1
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    g = build_graph(n, np.stack([src, dst], 1), np.zeros(n, np.int32))
    feats = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    d_max = int(g.max_in_degree)
    nbrs = pad_adjacency(g.in_indptr, g.in_indices, d_max)
    got = gather_aggregate_pallas(feats, jnp.asarray(nbrs), block_nodes=32,
                                  interpret=True)
    msgs = feats[jnp.asarray(src)]
    ref = scatter_sum(msgs, jnp.asarray(dst), jnp.ones(src.shape[0], bool), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
