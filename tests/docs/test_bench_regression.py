"""The bench-smoke regression gate (scripts/check_bench_regression.py):
gate semantics on synthetic trajectories + the committed BENCH_smoke.json
must pass against itself (the no-change CI invariant)."""
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]
SCRIPT = ROOT / "scripts" / "check_bench_regression.py"

spec = importlib.util.spec_from_file_location("check_bench_regression",
                                              SCRIPT)
gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(gate)


def _traj(rows):
    return {"schema": 1, "rows": rows}


def _row(name, us, parity=None):
    return {"name": name, "us_per_call": us, "derived": parity,
            "parity": parity}


def test_gate_blocks_regression_and_missing_rows():
    base = _traj([_row("exec_time/batched_level/n2000/P16", 100.0),
                  _row("exec_time/gnutella/s6/flexis_0.4", 200.0),
                  _row("exec_time/planner/compute_bound_P1/n2000", 50.0)])
    # within 1.3x everywhere → OK
    ok = _traj([_row("exec_time/batched_level/n2000/P16", 129.0),
                _row("exec_time/gnutella/s6/flexis_0.4", 10.0),
                _row("exec_time/planner/compute_bound_P1/n2000", 500.0)])
    failures, notes = gate.check(base, ok)
    assert failures == []
    assert any("ungated" in n for n in notes)  # planner row slower but free

    # gated row >1.3x slower → fail
    slow = _traj([_row("exec_time/batched_level/n2000/P16", 131.0),
                  _row("exec_time/gnutella/s6/flexis_0.4", 200.0),
                  _row("exec_time/planner/compute_bound_P1/n2000", 50.0)])
    failures, _ = gate.check(base, slow)
    assert len(failures) == 1 and "SLOWER" in failures[0]

    # gated row silently dropped → fail; new rows are fine
    dropped = _traj([_row("exec_time/gnutella/s6/flexis_0.4", 200.0),
                     _row("exec_time/gnutella/s6/new_variant", 1.0)])
    failures, notes = gate.check(base, dropped)
    assert any("MISSING" in f for f in failures)
    assert any("new row" in n for n in notes)


def test_gate_blocks_parity_loss():
    base = _traj([_row("exec_time/expansion_plane/xla/n1000/P8", 10.0,
                       parity=1.0)])
    good = _traj([_row("exec_time/expansion_plane/xla/n1000/P8", 99.0,
                       parity=1.0)])
    bad = _traj([_row("exec_time/expansion_plane/xla/n1000/P8", 10.0,
                      parity=0.0)])
    assert gate.check(base, good)[0] == []     # parity rows aren't timed
    failures, _ = gate.check(base, bad)
    assert len(failures) == 1 and "PARITY" in failures[0]


def test_gate_blocks_accuracy_loss():
    def srow(name, us, accuracy):
        return {"name": name, "us_per_call": us, "derived": 1.0,
                "accuracy": accuracy}

    base = _traj([srow("exec_time/sampled/gnutella/s20/f0.5", 10.0, 1.0)])
    good = _traj([srow("exec_time/sampled/gnutella/s20/f0.5", 11.0, 1.0)])
    bad = _traj([srow("exec_time/sampled/gnutella/s20/f0.5", 10.0, 0.0)])
    assert gate.check(base, good)[0] == []
    failures, _ = gate.check(base, bad)
    assert len(failures) == 1 and "ACCURACY" in failures[0]

    # unlike parity rows, accuracy rows stay timing-gated
    slow = _traj([srow("exec_time/sampled/gnutella/s20/f0.5", 100.0, 1.0)])
    failures, _ = gate.check(base, slow)
    assert len(failures) == 1 and "SLOWER" in failures[0]

    # a sampled row only present in the FRESH file gets no grace period
    fresh_only = _traj([srow("exec_time/sampled/gnutella/s20/f0.5", 10.0, 1.0),
                        srow("exec_time/sampled/gnutella/s20/f0.25", 9.0, 0.0)])
    failures, notes = gate.check(base, fresh_only)
    assert len(failures) == 1 and "ACCURACY" in failures[0]
    assert any("new row" in n for n in notes)


def test_committed_trajectory_passes_against_itself(tmp_path):
    committed = ROOT / "BENCH_smoke.json"
    assert committed.is_file()
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(committed), str(committed)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_committed_trajectory_has_planner_rows():
    rows = {r["name"]
            for r in json.loads((ROOT / "BENCH_smoke.json").read_text())["rows"]}
    assert any(n.startswith("exec_time/planner/") for n in rows), \
        "BENCH_smoke.json predates the execution planner — refresh it"
    assert any(n.startswith("exec_time/batched_level/") for n in rows)
