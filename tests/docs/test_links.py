"""Docs integrity: the markdown link check that CI's docs job runs must
pass locally too, and the docs tree the README promises must exist."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]


def test_docs_tree_exists():
    for name in ("architecture.md", "metrics.md", "kernels.md"):
        assert (ROOT / "docs" / name).is_file(), f"docs/{name} missing"


def test_readme_links_docs():
    readme = (ROOT / "README.md").read_text()
    for name in ("docs/architecture.md", "docs/metrics.md",
                 "docs/kernels.md"):
        assert name in readme, f"README does not link {name}"


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_links.py"), str(ROOT)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
