"""Graceful-shutdown CLI contract: SIGTERM → final snapshot → exit 75 →
rerun resumes to the bit-identical result.

Subprocess-based on purpose: the signal handler installation, the
PreemptedError → EXIT_PREEMPTED translation, and the async-save flush all
live in `repro.launch.mine` and only compose for real across an actual
process boundary.
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]
EXIT_PREEMPTED = 75  # keep in sync with repro.launch.mine


def _cmd(json_path, ckpt_dir=None):
    cmd = [sys.executable, "-m", "repro.launch.mine",
           "--dataset", "gnutella", "--scale", "0.02", "--sigma", "10",
           "--lam", "0.6", "--max-size", "3", "--cap", "4096",
           "--execution", "batched", "--json", str(json_path)]
    if ckpt_dir is not None:
        cmd += ["--checkpoint-dir", str(ckpt_dir), "--checkpoint-every", "1"]
    return cmd


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _norm(json_path):
    d = json.loads(Path(json_path).read_text())
    d.pop("elapsed_s", None)
    d.pop("health", None)  # a resumed run records recoveries; oracle never
    for lvl in d.get("per_level", {}).values():
        lvl.pop("wall_s", None)
    return d


def test_sigterm_preempts_resumably(tmp_path):
    env = _env()
    oracle_json = tmp_path / "oracle.json"
    subprocess.run(_cmd(oracle_json), env=env, check=True,
                   capture_output=True, text=True, timeout=600, cwd=ROOT)

    ckpt_dir = tmp_path / "ckpt"
    out_json = tmp_path / "out.json"
    proc = subprocess.Popen(_cmd(out_json, ckpt_dir), env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, cwd=ROOT)
    # wait until at least one snapshot committed, then ask it to stop
    deadline = time.time() + 300
    while (time.time() < deadline and proc.poll() is None
           and not list(ckpt_dir.glob("step_*/COMMIT"))):
        time.sleep(0.1)
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
    output, _ = proc.communicate(timeout=600)

    # either we caught it mid-run (preempted, resumable) or the run was
    # simply faster than the first COMMIT poll (finished clean) — both are
    # valid terminal states of the contract
    assert proc.returncode in (0, EXIT_PREEMPTED), output
    if proc.returncode == EXIT_PREEMPTED:
        assert "preempted" in output, output
        assert list(ckpt_dir.glob("step_*/COMMIT")), \
            "preempted exit without a committed snapshot"
        assert not out_json.exists()  # no result JSON for a partial run

    # rerunning the same command line resumes (or re-verifies) to the
    # bit-identical result — same diff the CI resume-smoke performs
    r2 = subprocess.run(_cmd(out_json, ckpt_dir), env=env,
                        capture_output=True, text=True, timeout=600,
                        cwd=ROOT)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert _norm(out_json) == _norm(oracle_json)
