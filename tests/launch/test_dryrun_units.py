"""Dry-run machinery units: HLO collective parser + divisibility fixup.

(The full 512-device dry-run grid is executed by launch/dryrun.py and
recorded in EXPERIMENTS.md — too heavy for CI; these tests cover its parts
on small meshes.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import jax_compat
from repro.launch.dryrun import (
    _fix_divisibility, collective_bytes_from_hlo,
)


def _mesh(shape, names):
    return jax_compat.make_mesh(shape, names)


def test_collective_parser_counts_psum():
    mesh = _mesh((1,), ("data",))

    def f(x):
        return jax.lax.psum(x, "data")

    x = jnp.ones((128, 64), jnp.float32)
    hlo = (
        jax.jit(jax_compat.shard_map(f, mesh=mesh, in_specs=(P(),),
                                     out_specs=P()))
        .lower(x).compile().as_text())
    stats = collective_bytes_from_hlo(hlo)
    assert stats["count"] >= 1
    assert stats["all-reduce"] >= 128 * 64 * 4


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[256,1024]{1,0} all-reduce(f32[256,1024]{1,0} %x), replica_groups={}
  %ag.1 = bf16[8,128]{1,0} all-gather(bf16[4,128]{1,0} %y), dimensions={0}
  %done = f32[4]{0} all-reduce-done(f32[4]{0} %start)
  %nothing = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    stats = collective_bytes_from_hlo(hlo)
    # the `-done` line is skipped (its shape is carried by the start op)
    assert stats["all-reduce"] == 256 * 1024 * 4
    assert stats["all-gather"] == 8 * 128 * 2


def _shape_only_mesh(shape, names):
    """_fix_divisibility/_axis_size read only axis_names + devices.shape, so
    tests can use a stub and stay independent of the process device count
    (a real (2, 4) mesh would need 8 devices — and whether that works would
    depend on whether another test initialized jax first)."""
    import types

    return types.SimpleNamespace(axis_names=tuple(names),
                                 devices=np.empty(shape))


def test_fix_divisibility_relocates_axis():
    mesh = _shape_only_mesh((2, 4), ("data", "model"))
    # 8 experts on a 4-way axis is fine; 6 is not → move to last dividing dim
    spec = _fix_divisibility(P("model", None, None), (6, 12, 16), mesh)
    assert spec == P(None, None, "model")
    # nothing to fix
    spec = _fix_divisibility(P("model", None), (8, 5), mesh)
    assert spec == P("model", None)
    # nowhere to go → dropped
    spec = _fix_divisibility(P("model",), (6,), mesh)
    assert spec == P(None)


def test_constrain_divisibility_guard():
    # needs a real 2-way model axis; forced host devices must be set before
    # jax initializes, so run isolated (same pattern as test_distributed)
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        from repro import jax_compat
        from repro.models.sharding import constrain, use_rules

        mesh = jax_compat.make_mesh((1, 2), ("data", "model"))
        with use_rules(mesh):
            @jax.jit
            def f(x):
                return constrain(x, "batch", None, "heads", None)

            # 3 heads on a 2-way model axis -> guard must drop the constraint
            out = f(jnp.ones((2, 4, 3, 8)))
            assert out.shape == (2, 4, 3, 8)
        print("CONSTRAIN_GUARD_OK", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=300,
        cwd=os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    assert "CONSTRAIN_GUARD_OK" in proc.stdout, proc.stderr[-3000:]
