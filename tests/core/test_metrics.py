"""MNI / fractional metrics vs paper ground truth + orderings."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, assume, HealthCheck

from repro.core import build_graph, paper_fig1
from repro.core import metrics as M
from tests.conftest import patterns, data_graphs


def _pad(embs, cap):
    k = embs.shape[1]
    out = np.full((cap, k), -1, np.int32)
    out[: embs.shape[0]] = embs
    return jnp.asarray(out), jnp.int32(embs.shape[0])


def test_mni_paper_fig1():
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = M.enumerate_embeddings_host(g, p1)
    emb, n_valid = _pad(embs, 16)
    st = M.mni_update(M.mni_init(3, 7), emb, n_valid, 3)
    assert int(M.mni_value(st)) == 3  # paper §2.4.4: F(u2)={d5,d6,d7} → 3


def test_frac_paper_fig1_below_mni():
    """§2.4.5: fractional reduces MNI's overestimate (MNI=3, MIS=2)."""
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = M.enumerate_embeddings_host(g, p1)
    emb, n_valid = _pad(embs, 16)
    st = M.frac_update(M.frac_init(3, 7), emb, n_valid, 3)
    v = float(M.frac_value(st))
    assert v <= 3.0


@settings(max_examples=50, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=14), patterns(min_k=2, max_k=3))
def test_metric_chain_mis_le_frac_le_mni(g, pat):
    """exact-MIS ≤ MNI and frac ≤ MNI (frac vs MIS can go either way in
    degenerate graphs, but MNI is always the ceiling)."""
    embs = M.enumerate_embeddings_host(g, pat, cap=3000)
    assume(embs.shape[0] <= 40)
    if embs.shape[0] == 0:
        return
    emb, n_valid = _pad(embs, max(16, embs.shape[0]))
    mni = int(M.mni_value(M.mni_update(M.mni_init(pat.k, g.n), emb, n_valid, pat.k)))
    frac = float(M.frac_value(M.frac_update(M.frac_init(pat.k, g.n), emb, n_valid, pat.k)))
    mis = M.exact_mis(embs)
    assert mis <= mni
    assert frac <= mni + 1e-5


def test_incremental_mni_equals_oneshot():
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = M.enumerate_embeddings_host(g, p1)
    st1 = M.mni_init(3, 7)
    emb, n_valid = _pad(embs, 16)
    st1 = M.mni_update(st1, emb, n_valid, 3)
    st2 = M.mni_init(3, 7)
    for half in (embs[:2], embs[2:]):
        emb_h, nv = _pad(half, 16)
        st2 = M.mni_update(st2, emb_h, nv, 3)
    np.testing.assert_array_equal(np.asarray(st1), np.asarray(st2))


def test_exact_mis_simple_cases():
    # disjoint embeddings -> all count
    embs = np.array([[0, 1], [2, 3], [4, 5]], np.int32)
    assert M.exact_mis(embs) == 3
    # chain conflicts: {0,1},{1,2},{2,3} -> pick 1st & 3rd
    embs = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    assert M.exact_mis(embs) == 2
    # paper Fig 4 tightness: hub mapping blocks all four spokes
    spokes = np.array([[0, 4, 5, 6], [1, 7, 8, 9], [2, 10, 11, 12], [3, 13, 14, 15]])
    hub = np.array([[0, 1, 2, 3]])
    embs = np.concatenate([hub, spokes]).astype(np.int32)
    assert M.exact_mis(embs) == 4  # MIS picks the four spokes
    assert len(M.greedy_mis_host(embs)) == 1  # greedy picks the hub: m=1, M=m·n
