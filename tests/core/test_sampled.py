"""Sampled execution plane — exactness, statistics, and planner gating.

Three layers of property tests (ISSUE 7):

  * **exactness invariant** — `execution="sampled"` with escalation
    returns the *identical* frequent-pattern set and supports as the
    forced-batched oracle, across metrics {mis, mis_luby, mni, frac} and
    sample fractions {0.25, 0.5, 1.0}; fraction 1.0 must degenerate to
    the exact plane with zero escalations;
  * **statistical machinery** — over ≥200 seeded draws from a per-block
    mass population measured on a real mining level, the nominal 95% CI
    covers the true support at ≥90% empirical rate and its mean width
    shrinks monotonically as the sample fraction grows;
  * **planner gating + calibration back-compat** — the sampled plan
    records a replayable draw, degenerates to batched when a sample
    cannot help, and schema-1 calibration files still load with the
    per-metric `row_time` accessor falling back to the shared constant.

Graphs are tiny on purpose: every claim here is structural/statistical,
not scale-dependent, and the full metric × fraction sweep must fit CI.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core import (
    CostModel, MatchConfig, MiningConfig, build_graph, load_calibration,
    mine,
)
from repro.core.planner import (
    CALIBRATION_ENV, ExecutionPlanner, LevelPlan, MIN_SAMPLED_BLOCKS,
    block_degree_stat,
)
from repro.core.sampled import (
    ht_estimate, ht_interval, normal_quantile, sample_key, sample_uniform,
    systematic_sample,
)

METRICS = ("mis", "mis_luby", "mni", "frac")
FRACTIONS = (0.25, 0.5, 1.0)


def _graph(n=64, deg=4, n_labels=3, seed=0):
    """Bounded-out-degree random digraph — several root blocks' worth."""
    rng = np.random.default_rng(seed)
    edges = set()
    for u in range(n):
        for v in rng.integers(0, n, deg):
            if u != int(v):
                edges.add((u, int(v)))
    labels = rng.integers(0, n_labels, n).astype(np.int32)
    return build_graph(n, sorted(edges), labels, n_labels=n_labels)


def _match_cfg():
    # root_block=8 → 8 blocks on the 64-vertex graph: enough schedule for
    # a 0.25 draw to be a real subset
    return MatchConfig(cap=256, root_block=8, chunk=8, max_chunks=2,
                       two_phase=False)


def _cfg(metric, execution, **kw):
    kw.setdefault("sigma", 6)
    kw.setdefault("max_pattern_size", 3)
    kw.setdefault("match", _match_cfg())
    return MiningConfig(metric=metric, execution=execution, **kw)


def _frequent(res):
    return [(p.key(), int(s)) for p, s in res.frequent]


def _freq_stats(res):
    """Full PatternStats of the frequent set (escalated ⇒ exact fields)."""
    return sorted(
        (st.pattern.key(), st.support, st.tau, st.embeddings_found,
         st.overflowed, st.blocks_run, st.max_count, st.estimated)
        for st in res.stats if st.frequent)


# ---------------------------------------------------------------------------
# the headline exactness invariant
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def oracle():
    """Forced-batched oracle result per metric (computed once)."""
    g = _graph()
    return g, {m: mine(g, _cfg(m, "batched")) for m in METRICS}


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("fraction", FRACTIONS)
def test_sampled_matches_batched_oracle(oracle, metric, fraction):
    g, refs = oracle
    ref = refs[metric]
    res = mine(g, _cfg(metric, "sampled", sample_fraction=fraction))
    assert _frequent(res) == _frequent(ref)
    # escalated patterns are exact — the frequent set's stats match the
    # oracle field-for-field (and are never flagged estimated)
    assert _freq_stats(res) == _freq_stats(ref)
    sampled_tel = [lvl["sampled"] for lvl in res.per_level.values()
                   if "sampled" in lvl]
    assert sampled_tel, "sampled plane never engaged"
    for tel in sampled_tel:
        assert 0 < tel["n_sample"] <= tel["n_blocks"]
        assert tel["escalated"] + tel["pruned"] >= 0
        if fraction == 1.0:
            assert tel["exact"] and tel["escalated"] == 0
        else:
            assert not tel["exact"]
    # infrequent prunes are flagged, and their supports sit below τ
    for st in res.stats:
        if st.estimated:
            assert not st.frequent and st.support < st.tau


def test_fraction_one_equals_batched_everywhere(oracle):
    """Fraction 1.0 is the exact plane: whole per_level trajectory matches
    (modulo the sampled plane's own bookkeeping keys)."""
    g, refs = oracle
    ref = refs["mis"]
    res = mine(g, _cfg("mis", "sampled", sample_fraction=1.0))
    drop = {"wall_s", "plan", "sampled", "block_peaks"}
    for lvl, st in ref.per_level.items():
        got = {k: v for k, v in res.per_level[lvl].items() if k not in drop}
        want = {k: v for k, v in st.items() if k not in drop}
        assert got == want, f"level {lvl}"
    assert all(not st.estimated for st in res.stats)


def test_sampled_deterministic(oracle):
    g, _ = oracle
    cfg = _cfg("mis", "sampled", sample_fraction=0.5)
    a, b = mine(g, cfg), mine(g, cfg)
    assert _frequent(a) == _frequent(b)
    assert [lvl.get("sampled") for lvl in a.per_level.values()] == \
           [lvl.get("sampled") for lvl in b.per_level.values()]


def test_escalation_disabled_is_flagged(oracle):
    """escalate=False trades exactness for speed — every sampled-level
    verdict is an estimate and says so."""
    g, _ = oracle
    res = mine(g, _cfg("mis", "sampled", sample_fraction=0.5,
                       escalate=False))
    est = [st for st in res.stats if st.estimated]
    assert est, "no estimated outcomes despite escalate=False"
    for lvl in res.per_level.values():
        if "sampled" in lvl and not lvl["sampled"]["exact"]:
            assert lvl["sampled"]["escalated"] == 0


# ---------------------------------------------------------------------------
# statistical machinery
# ---------------------------------------------------------------------------

def test_normal_quantile():
    assert normal_quantile(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert normal_quantile(0.5) == pytest.approx(0.0, abs=1e-12)
    assert normal_quantile(0.025) == pytest.approx(-1.959964, abs=1e-5)
    with pytest.raises(ValueError):
        normal_quantile(0.0)
    with pytest.raises(ValueError):
        normal_quantile(1.0)


def test_sample_uniform_deterministic_and_keyed():
    u = sample_uniform(sample_key(0, 1))
    assert u == sample_uniform(sample_key(0, 1))
    assert 0.0 <= u < 1.0
    assert u != sample_uniform(sample_key(0, 2))
    assert u != sample_uniform(sample_key(1, 1))


def test_systematic_sample_inclusion_probabilities():
    w = np.array([5.0, 1.0, 1.0, 1.0, 1.0, 1.0], np.float64)
    positions, pis = systematic_sample(w, 3, u=0.37)
    assert positions.shape[0] == 3
    assert np.all(np.diff(positions) > 0)
    # the heavy unit is a certainty unit: 3·(5/10) ≥ 1
    assert 0 in positions and pis[list(positions).index(0)] == 1.0
    # π sums to the sample size over the whole population
    _, all_pis = systematic_sample(w, 3, u=0.0)
    full = np.zeros(6)
    # recompute π for every unit via the definition: certainty unit 0,
    # remaining 2 slots spread evenly over 5 unit-weight units
    assert pis[0] == 1.0
    np.testing.assert_allclose(pis[1:], 2.0 / 5.0)
    del all_pis, full


def test_systematic_sample_degenerate():
    w = np.ones(4)
    p, pi = systematic_sample(w, 10, u=0.5)      # n ≥ m → everything
    assert list(p) == [0, 1, 2, 3] and np.all(pi == 1.0)
    p, pi = systematic_sample(w, 0, u=0.5)
    assert p.size == 0 and pi.size == 0
    with pytest.raises(ValueError):
        systematic_sample(np.array([1.0, -1.0]), 1, 0.5)


def test_ht_estimate_unbiased_over_u():
    """Averaging the HT total over a fine grid of the single uniform u
    reproduces the population total (systematic PPS is u-unbiased)."""
    rng = np.random.default_rng(7)
    y = rng.integers(0, 5, 12).astype(float)
    w = rng.random(12) + 0.1
    ests = []
    for u in np.linspace(0.0, 0.999, 200):
        pos, pis = systematic_sample(w, 4, float(u))
        ests.append(ht_estimate(y[pos], pis))
    assert np.mean(ests) == pytest.approx(y.sum(), rel=0.02)


def _population(metric="mis"):
    """Per-block support increments of a real level, complete coverage —
    the fixed population the coverage trials resample from."""
    g = _graph()
    from repro.core.flexis import initial_candidates, tau_threshold
    from repro.core.graph import DeviceGraph
    from repro.core.plan import make_plan
    from repro.core.sampled import sample_group

    cfg = _match_cfg()
    pats = initial_candidates(g)[:6]
    dev_g = DeviceGraph.from_host(g)
    taus = [tau_threshold(6, 0.4, p.k) for p in pats]
    n_blocks = -(-g.n // cfg.root_block)
    ys, outs, _, _, timed, _ = sample_group(
        dev_g, [make_plan(p, g) for p in pats], taus, metric, cfg, n=g.n,
        sampled_ids=np.arange(n_blocks, dtype=np.int64))
    assert not timed
    return np.asarray(ys, np.float64)


def test_ci_coverage_and_width_shrinks():
    """≥200 seeded trials: nominal 95% CI covers the true support at ≥90%,
    and the mean width is monotone non-increasing in the sample fraction."""
    pop = _population()                    # (P, m) per-block increments
    m = pop.shape[1]
    rng = np.random.default_rng(11)
    weights = rng.random(m) + 0.5          # a fixed, uneven draw weight
    trials = 220
    mean_widths = []
    for fraction in (0.25, 0.5, 0.75):
        n_sample = max(1, math.ceil(fraction * m))
        covered = total = 0
        widths = []
        for seed in range(trials):
            u = sample_uniform(sample_key(seed, 0))
            pos, pis = systematic_sample(weights, n_sample, u)
            for row in pop:
                truth = row.sum()
                est, lo, hi = ht_interval(row[pos], pis, m, 0.95)
                total += 1
                covered += bool(lo <= truth <= hi)
                if math.isfinite(hi - lo):
                    widths.append(hi - lo)
        assert covered / total >= 0.90, \
            f"coverage {covered / total:.3f} at fraction {fraction}"
        mean_widths.append(np.mean(widths))
    assert mean_widths[0] >= mean_widths[1] >= mean_widths[2], mean_widths


def test_ht_interval_edge_cases():
    # full coverage → zero-width exact interval
    est, lo, hi = ht_interval(np.array([2.0, 3.0]), np.array([1.0, 1.0]),
                              2, 0.95)
    assert est == lo == hi == 5.0
    # a single non-certainty draw → no variance estimate → infinite CI
    est, lo, hi = ht_interval(np.array([2.0, 1.0]), np.array([1.0, 0.4]),
                              5, 0.95)
    assert lo == -math.inf and hi == math.inf
    # all-zero sample → hidden-block bound, shrinking with coverage
    z = np.zeros(4)
    pis = np.full(4, 0.5)
    _, lo8, hi8 = ht_interval(z, pis, 8, 0.95)       # f = 0.5
    _, lo16, hi16 = ht_interval(z, pis, 16, 0.95)    # f = 0.25
    assert lo8 == lo16 == 0.0
    assert hi8 == pytest.approx(math.log(0.05) / math.log(0.5))
    assert hi16 > hi8


# ---------------------------------------------------------------------------
# planner gating + plan codec
# ---------------------------------------------------------------------------

def _planner(g, cfg):
    return ExecutionPlanner(g, cfg, cost_model=CostModel())


def test_plan_sampled_records_replayable_draw():
    g = _graph()
    cfg = _cfg("mis", "sampled", sample_fraction=0.5)
    from repro.core.flexis import initial_candidates
    pats = initial_candidates(g)
    plan = _planner(g, cfg).plan_level(1, pats, [3] * len(pats))
    assert plan.plane == "sampled"
    s = plan.sample
    assert s is not None and s["weights"] == "degree"
    assert s["key"] == sample_key(0, 1)
    assert len(s["positions"]) == s["n_sample"] == len(s["pis"])
    assert s["n_sample"] < -(-g.n // cfg.match.root_block)
    # JSON round-trip preserves the draw exactly (resume replays it)
    d = json.loads(json.dumps(plan.to_dict()))
    back = LevelPlan.from_dict(d, cfg.match)
    assert back.sample == s and back.plane == "sampled"
    # occupancy telemetry beats the degree fallback when present
    peaks = list(range(-(-g.n // cfg.match.root_block)))
    plan2 = _planner(g, cfg).plan_level(
        2, pats, [3] * len(pats), prev={"block_peaks": peaks})
    assert plan2.sample["weights"] == "occupancy"
    assert plan2.sample["positions"] != s["positions"] or \
        plan2.sample["key"] != s["key"]


def test_plan_sampled_degenerates_to_batched():
    g = _graph()
    from repro.core.flexis import initial_candidates
    pats = initial_candidates(g)
    # complete=True: every block must run → no sample can help
    cfg = _cfg("mis", "sampled", complete=True)
    assert _planner(g, cfg).plan_level(1, pats, [3] * len(pats)).plane \
        == "batched"
    # empty level
    cfg = _cfg("mis", "sampled")
    assert _planner(g, cfg).plan_level(1, [], []).plane == "batched"
    # too few blocks to both sample and leave something out
    big_block = dataclasses.replace(_match_cfg(), root_block=64)
    cfg = _cfg("mis", "sampled", match=big_block)
    p = _planner(g, cfg)
    assert p.n_blocks < MIN_SAMPLED_BLOCKS
    assert p.plan_level(1, pats, [3] * len(pats)).plane == "batched"
    # a fraction that rounds to full coverage stays sampled but unit-π
    cfg = _cfg("mis", "sampled", sample_fraction=1.0)
    plan = _planner(g, cfg).plan_level(1, pats, [3] * len(pats))
    assert plan.plane == "sampled" and plan.sample["fraction"] == 1.0
    assert all(p == 1.0 for p in plan.sample["pis"])


def test_auto_prices_sampled_by_tau_and_escalation():
    """The auto planner prices the sampled plane per level (ISSUE 10):
    below the hidden-mass bound it must stay exact (a zero-support pattern
    cannot be pruned there), above it the predicted escalation mass decides
    — and the whole decision, inputs included, rides in the plan."""
    g = _graph()
    from repro.core.flexis import initial_candidates
    from repro.core.planner import hidden_mass_bound
    pats = initial_candidates(g)

    # τ = 3 sits below the hidden-mass bound at f = 0.25 → batched, with
    # the pricing record explaining why
    pl = _planner(g, _cfg("mis", "auto"))
    plan = pl.plan_level(1, pats, [3] * len(pats))
    assert plan.plane in ("sequential", "batched", "distributed")
    assert plan.sample is None
    if plan.pricing is not None:
        assert plan.pricing["chosen"] == "batched"
        assert plan.pricing["tau_min"] <= plan.pricing["hidden_bound"]

    # τ far above the bound + telemetry showing everything pruned →
    # sampled wins, decision + draw recorded and JSON-replayable
    hidden = hidden_mass_bound(0.95, 0.25)
    tau = int(hidden) + 5
    prev = {"sampled": {"exact": False, "escalated": 0, "pruned": 20},
            "searched": 20, "frequent": 0}
    plan2 = _planner(g, _cfg("mis", "auto")).plan_level(
        2, pats, [tau] * len(pats), prev=prev)
    assert plan2.plane == "sampled" and plan2.sample is not None
    assert plan2.pricing["chosen"] == "sampled"
    assert plan2.pricing["esc_source"] == "telemetry"
    assert plan2.pricing["esc"] == 0.0
    assert plan2.pricing["sampled_s"] < plan2.pricing["batched_s"]
    d = json.loads(json.dumps(plan2.to_dict()))
    back = LevelPlan.from_dict(d, _match_cfg())
    assert back.pricing == plan2.pricing and back.sample == plan2.sample

    # ... but a prior of certain escalation makes sampling pointless even
    # at a huge τ (f·b + 1.0·((1−f)·b + replay) ≥ margin·b)
    prev_bad = {"sampled": {"exact": False, "escalated": 20, "pruned": 0},
                "searched": 20, "frequent": 20}
    plan3 = _planner(g, _cfg("mis", "auto")).plan_level(
        2, pats, [tau] * len(pats), prev=prev_bad)
    assert plan3.plane != "sampled"
    assert plan3.pricing is None or plan3.pricing["chosen"] == "batched"


def test_predict_escalation_chain():
    """telemetry → frontier → prior, most-informed first."""
    g = _graph()
    pl = _planner(g, _cfg("mis", "auto"))
    # no prev at all → the calibration prior
    from repro.core.planner import ESCALATION_PRIOR
    esc, src = pl._predict_escalation(None)
    assert (esc, src) == (ESCALATION_PRIOR, "prior")
    # sampled telemetry wins
    esc, src = pl._predict_escalation(
        {"sampled": {"exact": False, "escalated": 3, "pruned": 9},
         "searched": 12, "frequent": 12})
    assert src == "telemetry" and esc == pytest.approx(0.25)
    # exact (degenerate) sampled telemetry is no telemetry
    esc, src = pl._predict_escalation(
        {"sampled": {"exact": True, "escalated": 0, "pruned": 0},
         "searched": 10, "frequent": 5})
    assert src == "frontier"
    assert esc == pytest.approx(0.5 + ESCALATION_PRIOR * 0.5)
    # calibrated prior replaces the constant
    pl2 = ExecutionPlanner(g, _cfg("mis", "auto"),
                           cost_model=CostModel(escalation_fraction=0.1))
    esc, src = pl2._predict_escalation(None)
    assert (esc, src) == (0.1, "prior")


def test_block_degree_stat_indexes_block_ids():
    g = _graph()
    stat = block_degree_stat(g, 8)
    deg = np.diff(g.out_indptr)
    assert stat.shape[0] == -(-g.n // 8)
    assert int(stat[0]) == int(deg[:8].max())


def test_sampled_config_validation():
    with pytest.raises(ValueError):
        _cfg("mis_exact", "sampled")
    with pytest.raises(ValueError):
        _cfg("mis", "sampled", sample_fraction=0.0)
    with pytest.raises(ValueError):
        _cfg("mis", "sampled", sample_fraction=1.5)
    with pytest.raises(ValueError):
        _cfg("mis", "sampled", confidence=1.0)


# ---------------------------------------------------------------------------
# calibration schema 2 (per-metric row times) + schema-1 back-compat
# ---------------------------------------------------------------------------

def test_row_time_per_metric_with_fallback():
    cm = CostModel(row_time_s=4e-6, row_time_mni_s=1e-6)
    assert cm.row_time("mni") == 1e-6
    assert cm.row_time("mis") == 4e-6
    assert cm.row_time("frac") == 4e-6        # no override → shared constant
    assert cm.row_time("mis_luby") == 4e-6
    # the metric reaches the block-step estimate
    cfg = MatchConfig(cap=64, root_block=16, chunk=4, max_chunks=1)
    assert cm.block_step_s(cfg, 3, 1, batched=False, metric="mni") \
        < cm.block_step_s(cfg, 3, 1, batched=False, metric="mis")


def test_schema1_calibration_still_loads(tmp_path, monkeypatch):
    old = tmp_path / "old.json"
    old.write_text(json.dumps({
        "schema": 1, "dispatch_overhead_s": 1e-3, "lane_time_s": 1e-9,
        "row_time_s": 2e-6, "vmap_factor": 1.1}))
    monkeypatch.setenv(CALIBRATION_ENV, str(old))
    cm = load_calibration()
    assert cm.row_time_s == 2e-6
    # schema-1 files carry no per-metric overrides → shared constant
    for metric in METRICS:
        assert cm.row_time(metric) == 2e-6


def test_schema2_roundtrip(tmp_path, monkeypatch):
    cm = CostModel(row_time_s=4e-6, row_time_mni_s=1e-6,
                   row_time_frac_s=2e-6, row_time_luby_s=8e-6,
                   source="fit")
    f = tmp_path / "new.json"
    f.write_text(json.dumps(cm.to_dict()))
    monkeypatch.setenv(CALIBRATION_ENV, str(f))
    back = load_calibration()
    assert back == dataclasses.replace(cm, source=str(f))
    assert back.row_time("mis_luby") == 8e-6


# ---------------------------------------------------------------------------
# calibration schema 3 (measured escalation fraction) — ISSUE 10
# ---------------------------------------------------------------------------

def test_persist_escalation_fraction_ema_and_schema_upgrade(tmp_path):
    from repro.core.planner import (
        CALIBRATION_SCHEMA, persist_escalation_fraction,
    )
    # fresh file: the raw measurement lands as-is, schema stamped 3
    p = tmp_path / "cal.json"
    assert persist_escalation_fraction(0.4, path=str(p)) == str(p)
    d = json.loads(p.read_text())
    assert d["schema"] == CALIBRATION_SCHEMA
    assert d["escalation_fraction"] == pytest.approx(0.4)
    # second run folds in with EMA weight 0.5
    persist_escalation_fraction(0.0, path=str(p))
    assert json.loads(p.read_text())["escalation_fraction"] \
        == pytest.approx(0.2)
    # out-of-range measurements clamp before the EMA
    persist_escalation_fraction(7.5, path=str(p))
    assert json.loads(p.read_text())["escalation_fraction"] \
        == pytest.approx(0.6)
    # schema-1 files upgrade in place, preserving their fitted constants
    old = tmp_path / "old.json"
    old.write_text(json.dumps({
        "schema": 1, "dispatch_overhead_s": 1e-3, "lane_time_s": 1e-9,
        "row_time_s": 2e-6, "vmap_factor": 1.1}))
    persist_escalation_fraction(0.3, path=str(old))
    up = json.loads(old.read_text())
    assert up["schema"] == CALIBRATION_SCHEMA
    assert up["row_time_s"] == 2e-6
    assert up["escalation_fraction"] == pytest.approx(0.3)
    # and the loaded model's prior is the measured fraction
    cm = load_calibration(str(old))
    assert cm.escalation_fraction == pytest.approx(0.3)
    assert cm.esc_prior() == pytest.approx(0.3)


def test_schema12_load_leaves_prior_at_constant(tmp_path, monkeypatch):
    from repro.core.planner import ESCALATION_PRIOR
    f = tmp_path / "s2.json"
    f.write_text(json.dumps({
        "schema": 2, "dispatch_overhead_s": 1e-3, "lane_time_s": 1e-9,
        "row_time_s": 2e-6, "vmap_factor": 1.1, "row_time_mni_s": 1e-6}))
    monkeypatch.setenv(CALIBRATION_ENV, str(f))
    cm = load_calibration()
    assert cm.escalation_fraction is None
    assert cm.esc_prior() == ESCALATION_PRIOR


# ---------------------------------------------------------------------------
# RNG golden values — the draws below are part of the resume format: a
# numpy upgrade that shifts any of them would silently break replay of
# recorded sample rounds, so they are pinned to exact floats (ISSUE 10)
# ---------------------------------------------------------------------------

def test_rng_golden_values():
    assert sample_key(0, 1) == [0, 1]
    assert sample_key(3, 2) == [3, 2]
    k = sample_key(0, 1)
    assert sample_uniform(k) == 0.70962399485867
    # count=1 must be bit-identical to the historical single-draw form
    assert sample_uniform(k, count=1) == sample_uniform(k)
    # count=r+1 is the round-r uniform: a later round never disturbs an
    # earlier round's draw (same generator, last of r+1 variates)
    assert sample_uniform(k, count=2) == 0.9795624859036957
    assert sample_uniform(sample_key(3, 2), count=3) == 0.6850707717552736

    w = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])
    pos, pis = systematic_sample(w, 3, 0.5)
    assert pos.tolist() == [3, 5, 7]
    assert pis.tolist() == [
        0.3333333333333333, 0.5, 0.6666666666666666]
    from repro.core.sampled import inclusion_probs
    assert inclusion_probs(w, 3).tolist() == [
        0.08333333333333333, 0.16666666666666666, 0.25,
        0.3333333333333333, 0.4166666666666667, 0.5,
        0.5833333333333334, 0.6666666666666666]
    # the full-schedule vector agrees with the draw's own π at every
    # sampled position — the identity conditional PPS composes on
    assert inclusion_probs(w, 3)[pos].tolist() == pis.tolist()


# ---------------------------------------------------------------------------
# adaptive rounds + escalation reuse (direct level evaluation) — ISSUE 10
# ---------------------------------------------------------------------------

def _level_fixture(metric="mis", fraction=0.5):
    """One real level: graph, device graph, candidate patterns, the
    planner's recorded draw, and the complete-coverage exact outcomes."""
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import initial_candidates
    from repro.core.graph import DeviceGraph

    g = _graph()
    cfg = _cfg(metric, "sampled", sample_fraction=fraction)
    pats = initial_candidates(g)
    plan = _planner(g, cfg).plan_level(1, pats, [3] * len(pats))
    assert plan.plane == "sampled" and plan.sample is not None
    dev_g = DeviceGraph.from_host(g)
    exact, timed, _ = evaluate_level_batched(
        g, dev_g, pats, [1] * len(pats), metric, cfg.match, complete=True)
    assert not timed
    return g, dev_g, cfg, pats, plan, exact


def test_escalation_reuse_never_rematches_sampled_blocks():
    """Acceptance: with τ one above every true support nothing early-exits
    and nothing prunes, so the escalation walks the full schedule for
    every pattern — and the counters prove each sampled block is replayed,
    never re-matched.  All-escalate also means the settled-set CI width
    has no samples: `ci_width_mean` must be None (JSON null), not NaN."""
    from repro.core.sampled import evaluate_level_sampled

    g, dev_g, cfg, pats, plan, exact = _level_fixture("mis", 0.5)
    taus = [o.support + 1 for o in exact]
    m = -(-g.n // cfg.match.root_block)
    counters = {}
    outs, timed, tel = evaluate_level_sampled(
        g, dev_g, pats, taus, "mis", cfg.match, sample=plan.sample,
        confidence=cfg.confidence, escalate=True, max_batch=64,
        sample_rounds=1, counters=counters)
    assert not timed
    s = tel.sampled
    assert s["escalated"] == len(pats) and s["pruned"] == 0
    assert s["ci_width_mean"] is None
    assert "NaN" not in json.dumps(s, allow_nan=False)
    # every pattern escalated ⇒ exact outcomes, bit-identical to complete
    for o, e in zip(outs, exact):
        assert not o.estimated
        assert (o.support, o.embeddings_found, o.overflowed) \
            == (e.support, e.embeddings_found, e.overflowed)
    # one k=2 group (max_batch ≥ P): the full walk visits every block
    # exactly once per group — sampled positions via the update-only
    # replay step, the rest via real match steps
    n_groups = -(-len(pats) // 64)
    assert counters["replay_blocks"] == n_groups * s["n_sample"]
    assert counters["match_blocks"] == n_groups * (m - s["n_sample"])


def test_adaptive_rounds_grow_coverage_until_undecided_stops_shrinking():
    """Mixed τ: half the patterns sit far below an astronomic τ (the CI
    prunes them round 1), the rest straddle τ (stay undecided) — so the
    sampler must draw a second geometric round before handing the rest to
    escalation.  Escalated outcomes stay bit-identical to complete."""
    from repro.core.sampled import evaluate_level_sampled

    g, dev_g, cfg, pats, plan, exact = _level_fixture("mis", 0.5)
    taus = [10 ** 6 if i % 2 == 0 else exact[i].support + 1
            for i in range(len(pats))]
    outs, timed, tel = evaluate_level_sampled(
        g, dev_g, pats, taus, "mis", cfg.match, sample=plan.sample,
        confidence=cfg.confidence, escalate=True, max_batch=64,
        sample_rounds=3)
    assert not timed
    s = tel.sampled
    assert s["pruned"] >= 1 and s["escalated"] >= 1
    # round 1 pruned the easy half and left undecided mass → a further
    # round ran, and coverage grew beyond the plan's round-0 draw
    assert s["rounds"] >= 2
    assert s["n_sample"] > plan.sample["n_sample"]
    assert s["ci_width_mean"] is not None and s["ci_width_mean"] >= 0.0
    for i, (o, e) in enumerate(zip(outs, exact)):
        if taus[i] == 10 ** 6:
            assert o.estimated and not o.frequent
        else:
            assert not o.estimated
            assert (o.support, o.embeddings_found) \
                == (e.support, e.embeddings_found)
