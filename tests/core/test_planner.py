"""Execution planner — auto ≡ forced planes, cost-model properties,
degree-ordered root schedule, geometry derivation, calibration loading."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, HealthCheck

from repro.core import (
    CostModel,
    ExecutionPlanner,
    LevelPlan,
    MatchConfig,
    MiningConfig,
    build_graph,
    initial_candidates,
    load_calibration,
    mine,
    root_block_order,
)
from repro.core.planner import CAP_FLOOR, CALIBRATION_ENV
from repro.data.synthetic import rmat_graph
from tests.conftest import data_graphs

METRICS = ("mis", "mis_luby", "mni")


def _cfg(g, execution, metric="mis", **kw):
    # cap ≤ CAP_FLOOR and two_phase=False pin the geometry, so this config
    # isolates the *plane* decision (geometry derivation is tested
    # separately on graphs where occupancy is known)
    kw.setdefault("match", dataclasses.replace(
        MatchConfig.for_graph(g, cap=1024, root_block=32, chunk=4),
        two_phase=False))
    kw.setdefault("sigma", 2)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    return MiningConfig(metric=metric, execution=execution, **kw)


def _norm(res):
    """Everything plane-invariant: stats, frequent set, per-level counts
    minus wall clock, dispatch counts (amortized differently per plane)
    and the auto-only records (plan/pricing, sampled telemetry, occupancy
    weights and within-level replan counts — diagnostics of *how* a plane
    ran, not *what* it found)."""
    return dict(
        stats=[(s.pattern.key(), s.support, s.tau, s.frequent,
                s.embeddings_found, s.overflowed, s.blocks_run, s.max_count)
               for s in res.stats],
        frequent=[(p.key(), s) for p, s in res.frequent],
        searched=res.searched,
        per_level={
            lvl: {k: v for k, v in st.items()
                  if k not in ("wall_s", "dispatches", "plan", "sampled",
                               "block_peaks", "replans")}
            for lvl, st in res.per_level.items()},
        timed_out=res.timed_out,
    )


# ---------------------------------------------------------------------------
# auto ≡ forced planes (the acceptance property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(min_n=6, max_n=16, n_labels=2))
def test_auto_bit_identical_to_forced_planes(metric, g):
    auto = mine(g, _cfg(g, "auto", metric))
    seq = mine(g, _cfg(g, "sequential", metric))
    bat = mine(g, _cfg(g, "batched", metric))
    assert _norm(auto) == _norm(seq)
    assert _norm(auto) == _norm(bat)
    # and the decision trail exists for every mined level
    for st in auto.per_level.values():
        assert st["plan"]["plane"] in ("sequential", "batched")


def test_auto_geometry_derivation_preserves_results():
    """On a bounded-degree graph the planner shrinks cap below the
    oversized graph-global guess; results must not move vs forced planes."""
    rng = np.random.default_rng(0)
    n = 600
    src = np.repeat(np.arange(n), 2)
    dst = rng.integers(0, n, 2 * n)
    g = build_graph(n, np.stack([src, dst], 1), rng.integers(0, 4, n),
                    undirected=True)
    big = dataclasses.replace(
        MatchConfig.for_graph(g, cap=16384, root_block=64), two_phase=True)
    kw = dict(sigma=3, lam=1.0, max_pattern_size=3, complete=True, match=big)
    auto = mine(g, MiningConfig(execution="auto", **kw))
    bat = mine(g, MiningConfig(execution="batched", **kw))
    seq = mine(g, MiningConfig(execution="sequential", **kw))
    assert _norm(auto) == _norm(bat) == _norm(seq)
    # the planner actually derived a smaller frontier for level ≥ 2
    derived = [st["plan"]["cap"] for lvl, st in auto.per_level.items()
               if lvl >= 2]
    assert derived and all(c < big.cap for c in derived)
    assert not any(st["overflowed"] for st in auto.per_level.values())


def test_mis_exact_auto_equals_forced():
    g = rmat_graph(24, 60, n_labels=4, seed=9, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=1024, root_block=32)
    res = {}
    for ex in ("auto", "sequential", "batched"):
        res[ex] = mine(g, MiningConfig(
            sigma=2, lam=1.0, metric="mis_exact", max_pattern_size=3,
            match=cfg, execution=ex))
    assert _norm(res["auto"]) == _norm(res["sequential"]) \
        == _norm(res["batched"])


# ---------------------------------------------------------------------------
# cost-model properties
# ---------------------------------------------------------------------------

def _planner(g=None, execution="auto", cost=None, ndev=1, **cfg_kw):
    g = g if g is not None else rmat_graph(128, 700, n_labels=2, seed=1,
                                           undirected=True)
    cfg_kw.setdefault("sigma", 3)
    cfg_kw.setdefault("match", MatchConfig.for_graph(g, cap=1024,
                                                     root_block=32))
    cfg = MiningConfig(execution=execution, **cfg_kw)
    return ExecutionPlanner(g, cfg, cost_model=cost or CostModel(),
                            n_devices=ndev), g, cfg


def test_bucket_choice_monotone_in_pattern_count():
    """More patterns ⇒ never a smaller bucket (the acceptance unit test)."""
    for cost in (CostModel(),
                 CostModel(dispatch_overhead_s=1e-2, lane_time_s=1e-10),
                 CostModel(dispatch_overhead_s=1e-6, lane_time_s=1e-6,
                           vmap_factor=2.0)):
        planner, g, _ = _planner(cost=cost)
        prev = None
        for p_count in range(1, 200):
            bucket = planner.choose_bucket(p_count)
            assert bucket >= 1
            if prev is not None:
                assert bucket >= prev, (p_count, bucket, prev)
            prev = bucket


def test_plane_decision_regimes():
    planner, g, _ = _planner()
    cands = initial_candidates(g)
    assert len(cands) >= 4
    # single pattern: nothing to amortize — sequential (no vmap tax)
    assert planner.plan_level(1, cands[:1], [2]).plane == "sequential"
    # dispatch-bound: many patterns on a small grid — batched
    assert planner.plan_level(1, cands * 8, [2] * len(cands) * 8
                              ).plane == "batched"
    # forced modes pass through verbatim
    for forced in ("sequential", "batched"):
        pl, _, cfg = _planner(execution=forced)
        plan = pl.plan_level(1, cands[:4], [2] * 4)
        assert plan.plane == forced
        assert plan.match == cfg.match
        assert plan.max_batch == cfg.batch_patterns


def test_distributed_gating():
    """Auto may pick distributed only with metric=mis_luby, >1 device AND a
    pinned mesh-invariant super-block schedule."""
    g = rmat_graph(128, 700, n_labels=2, seed=1, undirected=True)
    match = MatchConfig.for_graph(g, cap=1024, root_block=16)  # 8 blocks
    make = lambda **kw: _planner(  # noqa: E731
        g=g, metric="mis_luby", ndev=4, match=match,
        cost=CostModel(dispatch_overhead_s=5e-3, lane_time_s=1e-10), **kw)
    planner, _, _ = make(blocks_per_super=4)
    cands = initial_candidates(g)[:4]
    assert planner.plan_level(1, cands, [2] * 4).plane == "distributed"
    # no pinned schedule → never distributed
    planner, _, _ = make()
    assert planner.plan_level(1, cands, [2] * 4).plane != "distributed"
    # wrong metric → never distributed (greedy scan isn't mesh-collective)
    planner, _, _ = _planner(
        g=g, metric="mis", ndev=4, blocks_per_super=4, match=match,
        cost=CostModel(dispatch_overhead_s=5e-3, lane_time_s=1e-10))
    assert planner.plan_level(1, cands, [2] * 4).plane != "distributed"


def test_derive_match_rules():
    planner, g, cfg = _planner()
    base = cfg.match
    # no telemetry → base geometry (two_phase passthrough is k-dependent)
    assert planner.derive_match(3, None).cap == base.cap
    # small occupancy → pow2(4×peak) clamped to the floor, never above base
    m = planner.derive_match(3, {"max_count": 10, "overflowed": False})
    assert m.cap == min(base.cap, CAP_FLOOR)
    # previous overflow → never shrink
    m = planner.derive_match(3, {"max_count": 10, "overflowed": True})
    assert m.cap == base.cap
    # ordering-sensitive knobs never move
    for prev in (None, {"max_count": 3, "overflowed": False}):
        m = planner.derive_match(3, prev)
        assert (m.chunk, m.max_chunks, m.root_block, m.bisect_iters) == \
            (base.chunk, base.max_chunks, base.root_block, base.bisect_iters)
    # two_phase derivation: k=2 has no non-anchor edge checks
    pl2, _, cfg2 = _planner(match=dataclasses.replace(
        MatchConfig.for_graph(g, cap=1024, root_block=32), two_phase=True))
    assert pl2.derive_match(2, None).two_phase is False
    assert pl2.derive_match(3, None).two_phase is True


def test_level_plan_dict_roundtrip():
    planner, g, cfg = _planner()
    cands = initial_candidates(g)
    plan = planner.plan_level(2, cands, [2] * len(cands),
                              prev={"max_count": 7, "overflowed": False})
    d = json.loads(json.dumps(plan.to_dict()))  # what the snapshot does
    back = LevelPlan.from_dict(d, cfg.match)
    assert back == plan
    assert back.to_dict() == plan.to_dict()


# ---------------------------------------------------------------------------
# degree-ordered root schedule
# ---------------------------------------------------------------------------

def test_root_block_order_is_degree_descending_permutation():
    g = rmat_graph(300, 2000, n_labels=2, seed=4, undirected=True)
    order = root_block_order(g, 32, "degree")
    n_blocks = -(-g.n // 32)
    assert sorted(order.tolist()) == list(range(n_blocks))
    deg = np.diff(g.out_indptr)
    pad = np.full(n_blocks * 32, -1, np.int64)
    pad[: deg.shape[0]] = deg
    block_max = pad.reshape(n_blocks, 32).max(axis=1)
    assert list(block_max[order]) == sorted(block_max, reverse=True)
    # ties stay in ascending block-id order (stable ⇒ deterministic)
    for a, b in zip(order, order[1:]):
        if block_max[a] == block_max[b]:
            assert a < b
    # vertex mode = identity
    assert root_block_order(g, 32, "vertex").tolist() == list(range(n_blocks))


@pytest.mark.parametrize("root_order", ["degree", "vertex"])
def test_root_order_plane_equivalence(root_order):
    """Both schedules keep every plane bit-identical to each other (the
    schedule is shared; only the cross-schedule values may differ)."""
    g = rmat_graph(200, 1200, n_labels=2, seed=3, undirected=True)
    cfg_kw = dict(sigma=4, lam=1.0, metric="mis", max_pattern_size=3,
                  root_order=root_order,
                  match=MatchConfig.for_graph(g, cap=1024, root_block=32))
    res = {ex: mine(g, MiningConfig(execution=ex, **cfg_kw))
           for ex in ("auto", "sequential", "batched")}
    assert _norm(res["auto"]) == _norm(res["sequential"]) \
        == _norm(res["batched"])


def test_degree_order_terminates_levels_in_fewer_blocks():
    """The point of the schedule: with all match roots (high out-degree
    vertices) at the END of the id range, vertex order scans every empty
    block before τ fires; degree order runs the root block first."""
    rng = np.random.default_rng(7)
    n = 512
    hubs = np.arange(n - 32, n)          # the only vertices with out-edges
    src = np.repeat(hubs, 24)
    dst = rng.integers(0, n - 32, src.shape[0])
    g = build_graph(n, np.stack([src, dst], 1), np.zeros(n, np.int32))
    cfg_kw = dict(sigma=8, lam=1.0, metric="mis", max_pattern_size=2,
                  match=MatchConfig.for_graph(g, cap=1024, root_block=32))
    by_order = {}
    for ro in ("degree", "vertex"):
        res = mine(g, MiningConfig(execution="sequential", root_order=ro,
                                   **cfg_kw))
        assert [p.key() for p, _ in res.frequent]  # something was mined
        by_order[ro] = sum(s.blocks_run for s in res.stats if s.frequent)
    assert by_order["degree"] < by_order["vertex"]


# ---------------------------------------------------------------------------
# calibration loading
# ---------------------------------------------------------------------------

def test_load_calibration(tmp_path, monkeypatch):
    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    # explicit path
    p = tmp_path / "cal.json"
    p.write_text(json.dumps({"schema": 1, "dispatch_overhead_s": 1e-3,
                             "lane_time_s": 2e-9, "vmap_factor": 1.5}))
    cm = load_calibration(str(p))
    assert (cm.dispatch_overhead_s, cm.lane_time_s, cm.vmap_factor) == \
        (1e-3, 2e-9, 1.5)
    # env var
    monkeypatch.setenv(CALIBRATION_ENV, str(p))
    assert load_calibration().lane_time_s == 2e-9
    monkeypatch.delenv(CALIBRATION_ENV)
    # malformed / wrong schema / missing → defaults, never an error
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_calibration(str(bad)) == CostModel()
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"schema": 99, "lane_time_s": 1.0}))
    assert load_calibration(str(wrong)) == CostModel()
    assert load_calibration(str(tmp_path / "nope.json")) == CostModel()
    # dict round-trip (what the session pins in snapshots)
    assert CostModel.from_dict(cm.to_dict()) == dataclasses.replace(
        cm, source=cm.source)


def test_config_validation():
    with pytest.raises(ValueError):
        MiningConfig(sigma=2, execution="planner")
    with pytest.raises(ValueError):
        MiningConfig(sigma=2, root_order="random")
    assert MiningConfig(sigma=2).execution == "auto"
    assert MiningConfig(sigma=2).root_order == "degree"
