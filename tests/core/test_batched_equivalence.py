"""Batched data plane ≡ sequential oracle, per pattern, for every metric.

The contract (core/batched.py): with ``execution="batched"`` every candidate
sees the exact same (block, metric-update) history as the sequential loop, so
(support, frequent, overflowed) — and even embeddings_found/blocks_run — are
bit-identical per pattern, early exit included.
"""
import numpy as np
import pytest
from hypothesis import given, settings, HealthCheck

from repro.core import MatchConfig, MiningConfig, mine
from repro.core.batched import (
    clear_program_cache, evaluate_level_batched, program_cache_stats,
)
from repro.core.flexis import evaluate_pattern, initial_candidates, tau_threshold
from repro.core.graph import DeviceGraph
from repro.data.synthetic import rmat_graph
from tests.conftest import data_graphs

METRICS = ("mis", "mis_luby", "mni")


def _cfg(g, metric, execution, **kw):
    kw.setdefault("match", MatchConfig.for_graph(g, cap=2048, root_block=32, chunk=4))
    kw.setdefault("sigma", 2)
    kw.setdefault("lam", 1.0)
    kw.setdefault("max_pattern_size", 3)
    return MiningConfig(metric=metric, execution=execution, **kw)


def _stat_triples(res):
    return [(s.support, s.frequent, s.overflowed) for s in res.stats]


def _per_level_counts(res):
    """per_level minus the telemetry keys that legitimately differ between
    planes (wall clock; dispatch counts — batched amortizes dispatches)."""
    return {lvl: {k: v for k, v in st.items()
                  if k not in ("wall_s", "dispatches")}
            for lvl, st in res.per_level.items()}


@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(min_n=6, max_n=16, n_labels=2))
def test_mine_batched_equals_sequential(metric, g):
    seq = mine(g, _cfg(g, metric, "sequential"))
    bat = mine(g, _cfg(g, metric, "batched"))
    assert _stat_triples(seq) == _stat_triples(bat)
    assert seq.searched == bat.searched
    assert _per_level_counts(seq) == _per_level_counts(bat)
    assert [(p.key(), s) for p, s in seq.frequent] == \
           [(p.key(), s) for p, s in bat.frequent]


@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(min_n=8, max_n=14, n_labels=2, p_edge_denom=3))
def test_mixed_k_levels_edge_ext(metric, g):
    """edge-extension levels mix pattern sizes — the batched plane groups by
    k and must still reproduce the sequential stats order."""
    seq = mine(g, _cfg(g, metric, "sequential", generation="edge_ext"))
    bat = mine(g, _cfg(g, metric, "batched", generation="edge_ext"))
    assert _stat_triples(seq) == _stat_triples(bat)
    assert seq.searched == bat.searched


@pytest.mark.parametrize("metric", METRICS + ("frac",))
@pytest.mark.parametrize("complete", (False, True))
def test_level_equivalence_exact_fields(metric, complete):
    """Field-for-field check on a fixed level, early exit and complete."""
    g = rmat_graph(300, 2000, n_labels=3, seed=4, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=128)
    cands = initial_candidates(g)[:12]
    taus = [tau_threshold(5, 1.0, p.k) for p in cands]
    mcfg = MiningConfig(sigma=5, lam=1.0, metric=metric, complete=complete,
                        match=cfg, execution="sequential")
    base = [evaluate_pattern(g, dg, p, t, mcfg) for p, t in zip(cands, taus)]
    outs, timed_out, _ = evaluate_level_batched(
        g, dg, cands, taus, metric, cfg, complete=complete)
    assert not timed_out
    for b, o in zip(base, outs):
        assert (b.support, b.frequent, b.overflowed) == \
               (o.support, o.frequent, o.overflowed)
        assert b.embeddings_found == o.embeddings_found
        assert b.blocks_run == o.blocks_run


@pytest.mark.parametrize("max_batch", (1, 3, 5))
def test_batch_slicing_preserves_equivalence(max_batch):
    """Levels bigger than batch_patterns are sliced; results must not move."""
    g = rmat_graph(300, 2000, n_labels=3, seed=4, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=128)
    cands = initial_candidates(g)[:12]
    taus = [tau_threshold(5, 1.0, p.k) for p in cands]
    ref, _, _ = evaluate_level_batched(g, dg, cands, taus, "mis", cfg)
    got, _, _ = evaluate_level_batched(g, dg, cands, taus, "mis", cfg,
                                       max_batch=max_batch)
    assert [(o.support, o.frequent, o.overflowed) for o in ref] == \
           [(o.support, o.frequent, o.overflowed) for o in got]


def test_program_cache_reuses_executables():
    """Levels (and repeat runs) must hit the step-program cache, not retrace."""
    g = rmat_graph(200, 1200, n_labels=2, seed=7, undirected=True)
    cfg = _cfg(g, "mis", "batched", sigma=3)
    clear_program_cache()
    mine(g, cfg)
    first = program_cache_stats()
    mine(g, cfg)
    second = program_cache_stats()
    assert second.misses == first.misses  # no new traces on a repeat run
    assert second.hits > first.hits


def test_mis_exact_falls_back_to_sequential():
    g = rmat_graph(24, 60, n_labels=4, seed=9, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=1024, root_block=32)
    a = mine(g, MiningConfig(sigma=2, lam=1.0, metric="mis_exact",
                             max_pattern_size=3, match=cfg,
                             execution="sequential"))
    b = mine(g, MiningConfig(sigma=2, lam=1.0, metric="mis_exact",
                             max_pattern_size=3, match=cfg,
                             execution="batched"))
    assert _stat_triples(a) == _stat_triples(b)


def test_batched_timeout_flag():
    g = rmat_graph(120, 700, n_labels=2, seed=5, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=1024, root_block=32)
    res = mine(g, MiningConfig(sigma=2, lam=0.0, metric="mis",
                               max_pattern_size=5, time_limit_s=0.0,
                               match=cfg, execution="batched"))
    assert res.timed_out
    assert res.searched == 0  # nothing ran a block before the deadline
