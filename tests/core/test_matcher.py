"""JAX frontier matcher vs the brute-force host oracle (property tests)."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, assume, HealthCheck

from repro.core import MatchConfig, make_plan, match_block
from repro.core.graph import DeviceGraph
from repro.core.matcher import edge_exists
from repro.core.metrics import enumerate_embeddings_host
from tests.conftest import patterns, data_graphs


def _all_embeddings(g, pat, cfg):
    """Run every root block; return embeddings in pattern-vertex order."""
    dg = DeviceGraph.from_host(g)
    plan = make_plan(pat, g)
    rows = []
    total_found = 0
    overflow = False
    for b in range(0, g.n, cfg.root_block):
        emb, count, found, ovf, _peak = match_block(dg, plan, jnp.int32(b), cfg)
        c = int(count)
        total_found += int(found)
        overflow |= bool(ovf)
        if c:
            rows.append(np.asarray(emb[:c]))
    got = np.concatenate(rows, 0) if rows else np.zeros((0, pat.k), np.int32)
    inv = np.argsort(np.array(plan.order))
    return got[:, inv], total_found, overflow


@settings(max_examples=60, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=16), patterns(min_k=2, max_k=4))
def test_matcher_matches_oracle(g, pat):
    cfg = MatchConfig.for_graph(g, cap=4096, root_block=8, chunk=4)
    got, found, overflow = _all_embeddings(g, pat, cfg)
    assume(not overflow)
    oracle = enumerate_embeddings_host(g, pat)
    got_set = set(map(tuple, got.tolist()))
    oracle_set = set(map(tuple, oracle.tolist()))
    assert got_set == oracle_set
    assert found == len(oracle_set)


@settings(max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=14), patterns(min_k=2, max_k=3))
def test_matcher_chunk_size_invariant(g, pat):
    """Chunked gathers must not change results across chunk geometries."""
    base = None
    for chunk in (1, 3, 8):
        cfg = MatchConfig.for_graph(g, cap=4096, root_block=16, chunk=chunk)
        got, _, overflow = _all_embeddings(g, pat, cfg)
        assume(not overflow)
        s = set(map(tuple, got.tolist()))
        if base is None:
            base = s
        else:
            assert s == base


def test_overflow_flag_and_clipping():
    """Tiny cap: matcher must flag overflow and never exceed capacity."""
    # star graph: hub label 0, many leaves label 1 — k=2 pattern explodes
    n = 40
    labels = [0] + [1] * (n - 1)
    edges = [(0, i) for i in range(1, n)]
    from repro.core import build_graph, pattern_from_edges

    g = build_graph(n, edges, labels)
    pat = pattern_from_edges([0, 1], [(0, 1)])
    cfg = MatchConfig.for_graph(g, cap=8, root_block=64, chunk=4)
    dg = DeviceGraph.from_host(g)
    plan = make_plan(pat, g)
    emb, count, found, ovf, peak = match_block(dg, plan, jnp.int32(0), cfg)
    assert bool(ovf)
    assert int(count) == 8
    assert int(found) == n - 1
    assert int(peak) == 8  # post-clip peak never exceeds cap


def test_edge_exists_bisect():
    rng = np.random.default_rng(3)
    n = 50
    m = rng.random((n, n)) < 0.15
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    from repro.core import build_graph

    g = build_graph(n, np.stack([src, dst], 1), np.zeros(n, np.int32))
    dg = DeviceGraph.from_host(g)
    u = jnp.asarray(rng.integers(0, n, size=500), jnp.int32)
    v = jnp.asarray(rng.integers(0, n, size=500), jnp.int32)
    iters = MatchConfig.for_graph(g).bisect_iters
    got = np.asarray(edge_exists(dg.out_indptr, dg.out_indices, u, v, iters))
    want = m[np.asarray(u), np.asarray(v)]
    np.testing.assert_array_equal(got, want)


def test_directed_vs_bidirectional_patterns():
    """A→B must not match where only B→A exists."""
    from repro.core import build_graph, pattern_from_edges

    g = build_graph(2, [(1, 0)], [0, 1])
    cfg = MatchConfig.for_graph(g, cap=16, root_block=4, chunk=2)
    pat_fwd = pattern_from_edges([0, 1], [(0, 1)])  # A→B
    pat_bwd = pattern_from_edges([0, 1], [], bidir=False).with_edge(1, 0)  # B→A
    got_f, _, _ = _all_embeddings(g, pat_fwd, cfg)
    got_b, _, _ = _all_embeddings(g, pat_bwd, cfg)
    assert got_f.shape[0] == 0
    assert got_b.shape[0] == 1
