"""mIS metric — Theorem 3.1 bounds, greedy/Luby equivalence, paper values."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, assume, HealthCheck

from repro.core import MatchConfig, make_plan, match_block, paper_fig1, build_graph
from repro.core.graph import DeviceGraph
from repro.core import mis as mis_lib
from repro.core.metrics import (
    enumerate_embeddings_host,
    exact_mis,
    greedy_mis_host,
)
from tests.conftest import patterns, data_graphs

BIG = jnp.int32(2**30)


def _emb_block(embs, cap):
    k = embs.shape[1] if embs.ndim == 2 else 1
    out = np.full((cap, max(k, 1)), -1, np.int32)
    if embs.shape[0]:
        out[: embs.shape[0]] = embs
    return jnp.asarray(out), jnp.int32(embs.shape[0])


def _device_greedy(embs, n, k, tau=None):
    cap = max(16, embs.shape[0])
    emb, cnt = _emb_block(embs, cap)
    bm, c = mis_lib.mis_greedy_update(
        mis_lib.bitmap_init(n), jnp.int32(0), emb, cnt,
        BIG if tau is None else jnp.int32(tau), k)
    return np.asarray(bm), int(c)


def _device_luby(embs, n, k, tau=None):
    cap = max(16, embs.shape[0])
    emb, cnt = _emb_block(embs, cap)
    bm, c = mis_lib.mis_luby_update(
        mis_lib.bitmap_init(n), jnp.int32(0), emb, cnt,
        BIG if tau is None else jnp.int32(tau), k, n)
    return np.asarray(bm), int(c)


def test_paper_fig1_values():
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = enumerate_embeddings_host(g, p1)
    assert exact_mis(embs) == 2           # paper: MIS = 2 (Fig 3d)
    _, m = _device_greedy(embs, 7, 3)
    assert m in (1, 2)                     # paper: mIS gives 1 or 2 (Fig 3c/3d)
    assert m == len(greedy_mis_host(embs))


@settings(max_examples=60, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=14), patterns(min_k=2, max_k=3))
def test_theorem_3_1_bounds(g, pat):
    """m ≤ M ≤ m·n for maximal m, maximum M, pattern size n."""
    embs = enumerate_embeddings_host(g, pat, cap=3000)
    assume(embs.shape[0] <= 40)
    if embs.shape[0] == 0:
        return
    M = exact_mis(embs)
    _, m = _device_greedy(embs, g.n, pat.k)
    assert m <= M <= m * pat.k


@settings(max_examples=60, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=16), patterns(min_k=2, max_k=3))
def test_greedy_equals_luby_complete(g, pat):
    """Run to completion: both implementations give the lexicographic MIS."""
    embs = enumerate_embeddings_host(g, pat, cap=5000)
    assume(embs.shape[0] <= 600)
    bm1, c1 = _device_greedy(embs, g.n, pat.k)
    bm2, c2 = _device_luby(embs, g.n, pat.k)
    assert c1 == c2
    np.testing.assert_array_equal(bm1, bm2)
    # and both equal the host greedy oracle
    assert c1 == len(greedy_mis_host(embs))


@settings(max_examples=40, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(max_n=14), patterns(min_k=2, max_k=3))
def test_selection_is_independent_and_maximal(g, pat):
    embs = enumerate_embeddings_host(g, pat, cap=5000)
    assume(0 < embs.shape[0] <= 600)
    bm, c = _device_greedy(embs, g.n, pat.k)
    # reconstruct used-vertex set from bitmap
    used = set()
    for w, word in enumerate(bm):
        for b in range(32):
            if word & np.uint32(1 << b):
                used.add(w * 32 + b)
    # independence: #used vertices == c * k (all distinct)
    assert len(used) == c * pat.k
    # maximality: no remaining embedding is fully outside `used`
    for row in embs:
        assert set(map(int, row)) & used, "non-maximal selection"


def test_early_exit_tau():
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = enumerate_embeddings_host(g, p1)
    for tau in (1, 2):
        _, c1 = _device_greedy(embs, 7, 3, tau=tau)
        _, c2 = _device_luby(embs, 7, 3, tau=tau)
        assert c1 == tau and c2 == tau


def test_cross_block_state_carrying():
    """Feeding embeddings in two chunks must equal one-shot selection."""
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    embs = enumerate_embeddings_host(g, p1)
    bm_all, c_all = _device_greedy(embs, 7, 3)
    bm = mis_lib.bitmap_init(7)
    cnt = jnp.int32(0)
    for half in (embs[:3], embs[3:]):
        emb, n_valid = _emb_block(half, 8)
        bm, cnt = mis_lib.mis_greedy_update(bm, cnt, emb, n_valid, BIG, 3)
    assert int(cnt) == c_all
    np.testing.assert_array_equal(np.asarray(bm), bm_all)
