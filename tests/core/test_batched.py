"""Batched-pattern matching ≡ per-pattern loop (same supports)."""
import numpy as np

from repro.core import (
    MatchConfig, MiningConfig, initial_candidates, tau_threshold,
)
from repro.core.batched import batched_mis_supports, stack_plans
from repro.core.flexis import evaluate_pattern
from repro.core.graph import DeviceGraph
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def test_batched_supports_equal_per_pattern():
    g = rmat_graph(300, 2000, n_labels=3, seed=4, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=128)
    cands = initial_candidates(g)[:12]
    taus = [tau_threshold(5, 1.0, p.k) for p in cands]
    mcfg = MiningConfig(sigma=5, lam=1.0, metric="mis", complete=True,
                        match=cfg)
    base = [evaluate_pattern(g, dg, p, t, mcfg).support
            for p, t in zip(cands, taus)]
    res = batched_mis_supports(g, cands, taus, cfg, complete=True)
    assert list(res.supports) == base
    assert not res.overflowed.any()


def test_batched_early_exit_reaches_tau():
    g = rmat_graph(200, 1500, n_labels=2, seed=1, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=64)
    cands = initial_candidates(g)[:4]
    res_full = batched_mis_supports(g, cands, [10**6] * len(cands), cfg,
                                    complete=True)
    taus = [max(1, int(s) // 2) for s in res_full.supports]
    res = batched_mis_supports(g, cands, taus, cfg)
    # early exit guarantees at least tau for patterns that can reach it
    for s, t, full in zip(res.supports, taus, res_full.supports):
        assert s >= min(t, full)


def test_stack_plans_shapes():
    g = rmat_graph(100, 600, n_labels=2, seed=2)
    cands = initial_candidates(g)[:3]
    plans = [make_plan(p, g) for p in cands]
    stacked = stack_plans(plans)
    assert stacked.anchor_pos.shape == (3, 2)
    assert stacked.check_out.shape == (3, 2, 2)


def test_unbatched_step_bit_identical_to_vmapped():
    """The P=1 no-vmap fast path must return exactly what the vmapped
    size-1 bucket returns (it replaces it transparently in _mine_group)."""
    import jax
    import jax.numpy as jnp

    from repro.core.batched import _state_init, _step_fn

    g = rmat_graph(200, 1200, n_labels=2, seed=5, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=512, root_block=64)
    pat = initial_candidates(g)[0]
    plans = stack_plans([make_plan(pat, g)])
    for metric in ("mis", "mis_luby", "mni", "frac"):
        state = _state_init(metric, 1, pat.k, g.n)
        taus = jnp.full((1,), 10**6, jnp.int32)
        outs = {}
        for unbatched in (False, True):
            step = _step_fn(metric, pat.k, cfg, unbatched=unbatched)
            outs[unbatched] = step(dg, plans, jnp.int32(0), state, taus)
        for a, b in zip(jax.tree_util.tree_leaves(outs[False]),
                        jax.tree_util.tree_leaves(outs[True])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collect_pattern_embeddings_matches_per_block_loop():
    """mis_exact's device half: block-batched collection == the one-block-
    per-dispatch loop, field for field, for any dispatch width."""
    import jax.numpy as jnp

    from repro.core.batched import collect_pattern_embeddings
    from repro.core.matcher import match_block

    g = rmat_graph(150, 900, n_labels=3, seed=8, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=1024, root_block=32)
    n_blocks = -(-g.n // cfg.root_block)
    rng = np.random.default_rng(0)
    order = rng.permutation(n_blocks).astype(np.int64)

    for pat in initial_candidates(g)[:3]:
        plan = make_plan(pat, g)
        ref_rows, ref_found, ref_ovf, ref_peak = [], 0, False, 0
        for b in order:
            emb, count, found, ovf, peak = match_block(
                dg, plan, jnp.int32(int(b) * cfg.root_block), cfg)
            c = int(count)
            if c:
                ref_rows.append(np.asarray(emb[:c]))
            ref_found += int(found)
            ref_ovf |= bool(ovf)
            ref_peak = max(ref_peak, int(peak))
        ref = (np.concatenate(ref_rows, 0) if ref_rows
               else np.zeros((0, pat.k), np.int32))
        for width in (1, 3, 8, 64):
            embs, found, ovf, blocks, peak, dispatches = \
                collect_pattern_embeddings(
                    dg, plan, cfg, g.n, block_order=order,
                    blocks_per_dispatch=width)
            np.testing.assert_array_equal(embs, ref)
            assert (found, ovf, blocks, peak) == \
                (ref_found, ref_ovf, n_blocks, ref_peak)
            assert dispatches == -(-n_blocks // width)
