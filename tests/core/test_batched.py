"""Batched-pattern matching ≡ per-pattern loop (same supports)."""
import numpy as np

from repro.core import (
    MatchConfig, MiningConfig, initial_candidates, tau_threshold,
)
from repro.core.batched import batched_mis_supports, stack_plans
from repro.core.flexis import evaluate_pattern
from repro.core.graph import DeviceGraph
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def test_batched_supports_equal_per_pattern():
    g = rmat_graph(300, 2000, n_labels=3, seed=4, undirected=True)
    dg = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=128)
    cands = initial_candidates(g)[:12]
    taus = [tau_threshold(5, 1.0, p.k) for p in cands]
    mcfg = MiningConfig(sigma=5, lam=1.0, metric="mis", complete=True,
                        match=cfg)
    base = [evaluate_pattern(g, dg, p, t, mcfg).support
            for p, t in zip(cands, taus)]
    res = batched_mis_supports(g, cands, taus, cfg, complete=True)
    assert list(res.supports) == base
    assert not res.overflowed.any()


def test_batched_early_exit_reaches_tau():
    g = rmat_graph(200, 1500, n_labels=2, seed=1, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=64)
    cands = initial_candidates(g)[:4]
    res_full = batched_mis_supports(g, cands, [10**6] * len(cands), cfg,
                                    complete=True)
    taus = [max(1, int(s) // 2) for s in res_full.supports]
    res = batched_mis_supports(g, cands, taus, cfg)
    # early exit guarantees at least tau for patterns that can reach it
    for s, t, full in zip(res.supports, taus, res_full.supports):
        assert s >= min(t, full)


def test_stack_plans_shapes():
    g = rmat_graph(100, 600, n_labels=2, seed=2)
    cands = initial_candidates(g)[:3]
    plans = [make_plan(p, g) for p in cands]
    stacked = stack_plans(plans)
    assert stacked.anchor_pos.shape == (3, 2)
    assert stacked.check_out.shape == (3, 2, 2)
