"""Distributed mining equivalence — runs a subprocess with 8 forced host
devices (XLA_FLAGS must be set before jax init, so not in-process)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8
    from repro.core import *
    from repro.core.distributed import distributed_support
    from repro.core.flexis import evaluate_pattern
    from repro.core.graph import DeviceGraph
    from repro.data.synthetic import rmat_graph

    g = rmat_graph(200, 1200, n_labels=2, seed=3, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=32)
    pats = initial_candidates(g)[:4]
    mcfg = MiningConfig(sigma=2, lam=1.0, metric="mis_luby", complete=True,
                        match=cfg)
    dg = DeviceGraph.from_host(g)
    for pat in pats:
        single = evaluate_pattern(g, dg, pat, tau=10**6, cfg=mcfg)
        dist, found = distributed_support(g, pat, tau=10**6, match_cfg=cfg,
                                          complete=True)
        assert dist == single.support, (pat, dist, single.support)
    # early exit returns exactly tau when enough embeddings exist
    pat = pats[0]
    full, _ = distributed_support(g, pat, tau=10**6, match_cfg=cfg,
                                  complete=True)
    if full >= 3:
        got, _ = distributed_support(g, pat, tau=3, match_cfg=cfg)
        assert got == 3, got
    print("DISTRIBUTED_OK", flush=True)
""")

_BATCHED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    assert len(jax.devices()) == 8
    from repro.core import MatchConfig, MiningConfig, initial_candidates
    from repro.core.distributed import distributed_batched_supports
    from repro.core.flexis import evaluate_pattern
    from repro.core.graph import DeviceGraph
    from repro.data.synthetic import rmat_graph

    g = rmat_graph(200, 1200, n_labels=2, seed=3, undirected=True)
    cfg = MatchConfig.for_graph(g, cap=2048, root_block=32)
    pats = initial_candidates(g)[:6]
    dg = DeviceGraph.from_host(g)
    mcfg = MiningConfig(sigma=2, lam=1.0, metric="mis_luby", complete=True,
                        match=cfg, execution="sequential")
    single = [evaluate_pattern(g, dg, p, 10**6, mcfg).support for p in pats]
    sup, found = distributed_batched_supports(
        g, pats, [10**6] * len(pats), match_cfg=cfg, complete=True)
    assert sup.tolist() == single, (sup.tolist(), single)
    # per-pattern early exit: every pattern reaches min(tau, full support)
    taus = [max(1, s // 2) for s in single]
    sup2, _ = distributed_batched_supports(g, pats, taus, match_cfg=cfg)
    for s2, t, full in zip(sup2, taus, single):
        assert s2 >= min(t, full), (s2, t, full)
    print("DISTRIBUTED_BATCHED_OK", flush=True)
""")


def _run_subprocess(script: str) -> "subprocess.CompletedProcess":
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__)))))


@pytest.mark.slow
def test_distributed_equals_single_device():
    proc = _run_subprocess(_SCRIPT)
    assert "DISTRIBUTED_OK" in proc.stdout, proc.stderr[-3000:]


@pytest.mark.slow
def test_distributed_batched_pattern_axis():
    """Roots sharded × patterns batched ≡ per-pattern single-device mining."""
    proc = _run_subprocess(_BATCHED_SCRIPT)
    assert "DISTRIBUTED_BATCHED_OK" in proc.stdout, proc.stderr[-3000:]
