"""End-to-end mining loop — Eq. 1, metric orderings, generator equivalence."""
import math

import numpy as np
from hypothesis import given, settings, assume, HealthCheck

from repro.core import (
    MatchConfig,
    MiningConfig,
    build_graph,
    canonical_key,
    mine,
    paper_fig1,
    tau_threshold,
)
from tests.conftest import data_graphs


def _cfg(g, **kw):
    kw.setdefault("match", MatchConfig.for_graph(g, cap=4096, root_block=32, chunk=4))
    return MiningConfig(**kw)


def test_eq1_endpoints():
    # λ=1 → τ=σ ; λ=0 → τ=⌊σ/n⌋ (paper §3.1.1)
    for sigma in (2, 7, 100):
        for n in (2, 3, 5):
            assert tau_threshold(sigma, 1.0, n) == sigma
            assert tau_threshold(sigma, 0.0, n) == max(1, math.floor(sigma / n))
    # paper's worked example: σ=2, λ=0.25, n=3 → τ=1
    assert tau_threshold(2, 0.25, 3) == 1


def test_paper_fig1_frequency_scenarios():
    """§3.1.1: σ=3 ⇒ P1 infrequent under mIS, frequent under MNI;
    σ=2, λ=1 ⇒ frequent under mIS iff greedy finds the 2-set."""
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)

    res_mni = mine(g, _cfg(g, sigma=3, metric="mni", max_pattern_size=3))
    freq_mni = {canonical_key(p) for p, _ in res_mni.frequent}
    assert canonical_key(p1) in freq_mni  # MNI=3 ≥ 3

    res_mis = mine(g, _cfg(g, sigma=3, lam=1.0, metric="mis", max_pattern_size=3))
    freq_mis = {canonical_key(p) for p, _ in res_mis.frequent}
    assert canonical_key(p1) not in freq_mis  # mIS ≤ MIS = 2 < 3

    res2 = mine(g, _cfg(g, sigma=2, lam=1.0, metric="mis", max_pattern_size=3))
    sup = {canonical_key(p): s for p, s in res2.frequent}
    assert sup.get(canonical_key(p1)) == 2


@settings(max_examples=15, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(min_n=8, max_n=14, n_labels=2))
def test_metric_ordering_mis_le_mni(g):
    """For every searched pattern: mIS support ≤ MNI support (complete runs)."""
    cfg_m = _cfg(g, sigma=2, lam=1.0, metric="mis", max_pattern_size=3, complete=True)
    cfg_n = _cfg(g, sigma=2, metric="mni", max_pattern_size=3, complete=True)
    res_m, res_n = mine(g, cfg_m), mine(g, cfg_n)
    mni = {canonical_key(s.pattern): s.support for s in res_n.stats}
    for s in res_m.stats:
        if s.overflowed:
            continue
        key = canonical_key(s.pattern)
        if key in mni:
            assert s.support <= mni[key], (s.pattern, s.support, mni[key])


@settings(max_examples=10, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(data_graphs(min_n=6, max_n=12, n_labels=2, p_edge_denom=5))
def test_generators_agree_on_frequent_sets(g):
    """merge vs edge-extension generation: same frequent patterns under MNI
    (deterministic metric), sizes ≤ 3 — Theorem 3.6 in practice."""
    cfg_a = _cfg(g, sigma=2, metric="mni", generation="merge", max_pattern_size=3)
    cfg_b = _cfg(g, sigma=2, metric="mni", generation="edge_ext", max_pattern_size=3)
    fa = {canonical_key(p) for p, _ in mine(g, cfg_a).frequent}
    fb = {canonical_key(p) for p, _ in mine(g, cfg_b).frequent}
    assert fa == fb


def test_searched_counts_merge_leq_edge_ext():
    """The paper's Table 2 direction: merging searches fewer candidates."""
    rng = np.random.default_rng(7)
    n = 30
    labels = rng.integers(0, 2, n)
    m = rng.random((n, n)) < 0.1
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    g = build_graph(n, np.stack([src, dst], 1), labels)
    a = mine(g, _cfg(g, sigma=3, lam=1.0, metric="mis", generation="merge",
                     max_pattern_size=4))
    b = mine(g, _cfg(g, sigma=3, lam=1.0, metric="mis", generation="edge_ext",
                     max_pattern_size=4))
    assert a.searched <= b.searched


def test_slider_monotonicity():
    """Higher λ ⇒ higher τ ⇒ fewer (or equal) frequent patterns (Fig 13b)."""
    rng = np.random.default_rng(11)
    n = 24
    labels = rng.integers(0, 2, n)
    m = rng.random((n, n)) < 0.15
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    g = build_graph(n, np.stack([src, dst], 1), labels)
    counts = []
    for lam in (0.0, 0.5, 1.0):
        res = mine(g, _cfg(g, sigma=4, lam=lam, metric="mis", max_pattern_size=3))
        counts.append(len(res.frequent))
    assert counts[0] >= counts[1] >= counts[2]


def test_timeout_flag():
    rng = np.random.default_rng(5)
    n = 60
    labels = rng.integers(0, 2, n)
    m = rng.random((n, n)) < 0.2
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    g = build_graph(n, np.stack([src, dst], 1), labels)
    res = mine(g, _cfg(g, sigma=2, lam=0.0, metric="mis", max_pattern_size=5,
                       time_limit_s=0.0))
    assert res.timed_out
