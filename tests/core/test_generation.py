"""Generation step — Lemmas 3.3/3.4/3.5 and Theorem 3.6 as properties."""
import numpy as np
from hypothesis import given, settings, assume

from repro.core import (
    Pattern,
    canonical_key,
    core_graphs,
    core_groups,
    dedupe_patterns,
    generate_new_patterns,
    edge_extension_candidates,
    pattern_from_edges,
    paper_fig1,
)
from tests.conftest import patterns


def _connected_subpatterns(pat):
    subs = []
    for v in range(pat.k):
        sp = pat.remove_vertex(v)
        if sp.is_connected():
            subs.append(sp)
    return subs


def test_core_graphs_of_p1():
    # paper §2.3.3 lists three core graphs for P1: C1^u1, C1^u3 (endpoints,
    # connected Γ) and C1^u2 (center, Γ = two isolated A-vertices).
    p1, _, _ = paper_fig1()
    cgs = core_graphs(p1)
    assert len(cgs) == 3
    by_label = sorted(cg.marked_label for cg in cgs)
    assert by_label == [0, 0, 1]  # two A-marked endpoint cores + one B-marked


def test_core_group_isomorphic_cores_share_key():
    # paper §2.3.2: C1^u1 isomorphic to C1^u3; C1^u2 is its own group
    p1, _, _ = paper_fig1()
    groups = core_groups([p1])
    assert len(groups) == 2
    sizes = sorted(len(cgs) for cgs in groups.values())
    assert sizes == [1, 2]


@settings(max_examples=120, deadline=None)
@given(patterns(min_k=3, max_k=5))
def test_lemma_3_4_completeness(pat):
    """Every connected k-pattern is generated from its (k−1)-subpatterns.

    (Lemma 3.4 for non-cliques, Lemma 3.5 + Alg 4 for cliques; together
    Theorem 3.6.) We feed ALL connected (k−1)-subpatterns of `pat` as the
    'frequent' set; `pat` must appear among the candidates.
    """
    subs = dedupe_patterns(_connected_subpatterns(pat))
    assume(len(subs) > 0)
    cands = generate_new_patterns(subs, downward_closure=False)
    keys = {canonical_key(c) for c in cands}
    assert canonical_key(pat) in keys


@settings(max_examples=60, deadline=None)
@given(patterns(min_k=3, max_k=5))
def test_candidates_are_valid(pat):
    subs = dedupe_patterns(_connected_subpatterns(pat))
    assume(len(subs) > 0)
    cands = generate_new_patterns(subs, downward_closure=False)
    # no duplicates, all connected, all one vertex larger
    keys = [canonical_key(c) for c in cands]
    assert len(keys) == len(set(keys))
    for c in cands:
        assert c.k == pat.k
        assert c.is_connected()


def test_clique_generation_triangle_to_4clique():
    """Lemma 3.5 shape: 4-clique requires three 3-cliques (paper Fig 8)."""
    tri = pattern_from_edges([0, 0, 0], [(0, 1), (1, 2), (0, 2)], bidir=True)
    cands = generate_new_patterns([tri], downward_closure=True)
    four_cliques = [c for c in cands if c.k == 4 and c.is_clique()]
    assert len(four_cliques) >= 1


def test_clique_generation_blocked_when_subclique_missing():
    """A 4-clique candidate is discarded if a 3-subclique isn't frequent."""
    # two distinct 3-patterns that are NOT both cliques cannot complete one
    tri = pattern_from_edges([0, 0, 1], [(0, 1), (1, 2), (0, 2)], bidir=True)
    path = pattern_from_edges([0, 0, 1], [(0, 1), (1, 2)], bidir=True)
    cands = generate_new_patterns([path], downward_closure=True)
    assert not any(c.is_clique() and c.k == 4 for c in cands)
    del tri


def test_merge_with_automorphism_paper_fig7():
    """Paper Fig 7: merging C^u4 with itself under the Γ-automorphism that
    swaps the two red triangle vertices yields BOTH 5-vertex variants."""
    # P: triangle u1(blue), u2(red), u3(red) + pendant u4(green) on u2
    P = pattern_from_edges(
        [0, 1, 1, 2],
        [(0, 1), (1, 2), (0, 2), (1, 3)],
        bidir=True,
    )
    cands = generate_new_patterns([P], downward_closure=False)
    five = [c for c in cands if c.k == 5]
    # among them: two greens on same red (Fig 7b-left) and greens on the two
    # different reds (Fig 7b-right)
    def degree_multiset(c):
        und = c.undirected_adj()
        greens = [i for i in range(c.k) if c.labels[i] == 2]
        reds = [i for i in range(c.k) if c.labels[i] == 1]
        # count greens attached per red
        counts = sorted(int(sum(und[g, r] for g in greens)) for r in reds)
        return tuple(counts)

    shapes = {degree_multiset(c) for c in five if (c.labels == 2).sum() == 2}
    assert (0, 2) in shapes  # both pendants on one red
    assert (1, 1) in shapes  # pendants split across reds (automorphism case)


@settings(max_examples=40, deadline=None)
@given(patterns(min_k=3, max_k=4))
def test_edge_extension_also_complete_per_edge(pat):
    """The baseline generator grows by one edge; any pattern with e+1 edges
    is reachable from one of its e-edge connected sub-patterns."""
    edges = pat.edges()
    assume(len(edges) >= 2)
    # remove one edge keeping connectivity
    for (i, j) in edges:
        adj = pat.adj.copy()
        adj[i, j] = False
        smaller = Pattern(adj, pat.labels)
        und = smaller.undirected_adj()
        if not smaller.is_connected():
            continue
        cands = edge_extension_candidates([smaller], pat.labels.tolist())
        keys = {canonical_key(c) for c in cands}
        assert canonical_key(pat) in keys
        return
