"""Canonicalization + automorphisms — correctness vs brute force."""
import numpy as np
from hypothesis import given, settings

from repro.core import (
    Pattern,
    are_isomorphic,
    automorphisms,
    canonical_form,
    canonical_key,
    pattern_from_edges,
    paper_fig1,
)
from tests.conftest import patterns


def test_paper_p1_automorphisms():
    # paper §2.1.3: P1 has exactly two automorphisms — identity and the
    # u1<->u3 swap (same label), u2 fixed.
    p1, _, _ = paper_fig1()
    auts = automorphisms(p1)
    assert auts.shape == (2, 3)
    assert auts[0].tolist() == [0, 1, 2]
    assert auts[1].tolist() == [2, 1, 0]


def test_unlabeled_triangle_six_automorphisms():
    # paper §2.1.3: if all vertices of P1 had the same label -> 3! = 6
    p = pattern_from_edges([0, 0, 0], [(0, 1), (1, 2)], bidir=True)
    p = p.with_edge(0, 2).with_edge(2, 0)  # make full triangle for symmetry
    assert automorphisms(p).shape[0] == 6


def test_path_same_labels():
    # path a-b-c with all labels equal: only identity and reversal
    p = pattern_from_edges([0, 0, 0], [(0, 1), (1, 2)], bidir=True)
    assert automorphisms(p).shape[0] == 2


@settings(max_examples=150, deadline=None)
@given(patterns(max_k=5))
def test_canonical_key_permutation_invariant(pat):
    rng = np.random.default_rng(hash(pat.key()) % 2**32)
    perm = rng.permutation(pat.k)
    assert canonical_key(pat) == canonical_key(pat.permuted(perm))
    assert are_isomorphic(pat, pat.permuted(perm))


@settings(max_examples=100, deadline=None)
@given(patterns(max_k=4), patterns(max_k=4))
def test_canonical_key_separates_nonisomorphic(a, b):
    # brute-force isomorphism check as oracle
    import itertools

    def brute_iso(x, y):
        if x.k != y.k:
            return False
        for perm in itertools.permutations(range(x.k)):
            if np.array_equal(x.permuted(perm).adj, y.adj) and np.array_equal(
                x.permuted(perm).labels, y.labels
            ):
                return True
        return False

    assert are_isomorphic(a, b) == brute_iso(a, b)


@settings(max_examples=80, deadline=None)
@given(patterns(max_k=5))
def test_canonical_form_is_fixed_point(pat):
    cf = canonical_form(pat)
    assert canonical_key(cf) == canonical_key(pat)
    assert cf.key() == canonical_form(cf).key()


@settings(max_examples=80, deadline=None)
@given(patterns(max_k=5))
def test_automorphisms_are_closed_group(pat):
    auts = automorphisms(pat)
    # identity first
    assert auts[0].tolist() == list(range(pat.k))
    # every automorphism preserves the pattern
    for a in auts:
        q = pat.permuted(a)
        assert np.array_equal(q.adj, pat.adj) and np.array_equal(q.labels, pat.labels)
    # closed under composition
    aset = {tuple(a.tolist()) for a in auts}
    for a in auts[:6]:
        for b in auts[:6]:
            comp = tuple(int(a[x]) for x in b)
            assert comp in aset
