"""mIS — the paper's Maximal-Independent-Set support metric, on device.

Two interchangeable implementations compute the *identical* set — the
lexicographically-first maximal independent set in embedding-row order —
when run to completion (τ = ∞).  Under early exit (τ reached mid-selection)
each returns *some* valid independent set of size τ, which may differ
between the two — exactly the paper's contract, where mIS is any maximal
set (Fig. 3c vs 3d) and early termination returns any τ-subset (§3.1.1):

  * ``mis_greedy_update`` — a sequential ``lax.scan`` over embedding rows
    carrying a packed uint32 used-vertex bitmap (mirrors the paper's shared
    bitmap across VF3 states).  A Pallas kernel version keeps the bitmap
    VMEM-resident (see ``repro.kernels.mis_bitmap``).

  * ``mis_luby_update`` — parallel rounds: an embedding is accepted in a
    round iff its priority (row index) is the minimum over every data vertex
    it touches.  With unique priorities this is exactly the greedy result
    (lexicographically-first MIS), in O(log) expected rounds, and each round
    reduces to one dense per-vertex ``min`` — which becomes a single
    ``all-reduce(min)`` when embeddings are sharded across devices
    (``core/distributed.py``).  This equivalence is property-tested.

The bitmap/count state persists across root blocks so the host loop can
early-terminate as soon as count ≥ τ (the paper's key speed lever).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "bitmap_init",
    "mis_greedy_update",
    "mis_luby_update",
    "touches_used",
]


def bitmap_words(n: int) -> int:
    return (n + 31) // 32


def bitmap_init(n: int) -> jnp.ndarray:
    """Packed used-vertex bitmap for a data graph of n vertices."""
    return jnp.zeros(bitmap_words(n), dtype=jnp.uint32)


def touches_used(bitmap: jnp.ndarray, verts: jnp.ndarray) -> jnp.ndarray:
    """For (rows, k) vertex ids: does any vertex have its bit set?"""
    words = (verts >> 5).astype(jnp.int32)
    bits = (jnp.uint32(1) << (verts & 31).astype(jnp.uint32))
    return jnp.any((bitmap[words] & bits) != 0, axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def mis_greedy_update(
    bitmap: jnp.ndarray,
    count: jnp.ndarray,
    emb: jnp.ndarray,
    n_valid: jnp.ndarray,
    tau: jnp.ndarray,
    k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy maximal-independent-set selection, row order = priority.

    emb: (cap, K) int32 with the first `k` columns valid; vertices within a
    row must be distinct (guaranteed by the matcher's injectivity check —
    the scatter-add-as-OR trick relies on it). Returns updated
    (bitmap, count).
    """
    cap = emb.shape[0]
    rows_valid = jnp.arange(cap, dtype=jnp.int32) < n_valid

    def body(carry, xs):
        bm, cnt = carry
        row, valid = xs
        vs = jnp.clip(row[:k], 0, None)
        words = (vs >> 5).astype(jnp.int32)
        bits = jnp.uint32(1) << (vs & 31).astype(jnp.uint32)
        free = jnp.all((bm[words] & bits) == 0)
        take = valid & free & (cnt < tau)
        # distinct vertices ⇒ distinct (word, bit) pairs; under `take` none of
        # the bits are set, so scatter-add of the bit values is exactly OR.
        bm = bm.at[words].add(jnp.where(take, bits, jnp.uint32(0)))
        return (bm, cnt + take.astype(jnp.int32)), None

    (bitmap, count), _ = jax.lax.scan(body, (bitmap, count), (emb, rows_valid))
    return bitmap, count


@functools.partial(jax.jit, static_argnames=("k", "n"))
def mis_luby_update(
    bitmap: jnp.ndarray,
    count: jnp.ndarray,
    emb: jnp.ndarray,
    n_valid: jnp.ndarray,
    tau: jnp.ndarray,
    k: int,
    n: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Parallel-rounds mIS (priority = row index). Same result as greedy.

    Each round: per-data-vertex min of alive embedding priorities
    (`segment`-style scatter-min into a dense (n,) array), then an embedding
    wins iff it holds the min on all k of its vertices.  Winners' vertices
    are retired into the bitmap.  The τ cut keeps the lowest-priority winners
    of the final round, so exactly τ embeddings are counted; under early exit
    the *set* may differ from the greedy scan's (see module docstring) but
    both are valid independent τ-sets.
    """
    cap = emb.shape[0]
    rowid = jnp.arange(cap, dtype=jnp.int32)
    vs = jnp.clip(emb[:, :k], 0, None)
    valid = rowid < n_valid

    def touches(bm):
        return touches_used(bm, vs)

    state0 = (bitmap, count, valid & ~touches(bitmap))

    def cond(state):
        bm, cnt, alive = state
        return jnp.any(alive) & (cnt < tau)

    def body(state):
        bm, cnt, alive = state
        INF = jnp.int32(cap)
        prio = jnp.where(alive, rowid, INF)
        vmin = jnp.full((n,), INF, dtype=jnp.int32)
        vmin = vmin.at[vs].min(prio[:, None])
        win = alive & jnp.all(vmin[vs] == prio[:, None], axis=1)
        # enforce τ in priority order: only the lowest (τ − cnt) winners count
        win_rank = jnp.cumsum(win.astype(jnp.int32)) - 1
        win &= win_rank < (tau - cnt)
        words = (vs >> 5).astype(jnp.int32)
        bits = jnp.uint32(1) << (vs & 31).astype(jnp.uint32)
        bm = bm.at[words].add(jnp.where(win[:, None], bits, jnp.uint32(0)))
        cnt = cnt + win.sum().astype(jnp.int32)
        alive = alive & ~win & ~touches_used(bm, vs)
        return bm, cnt, alive

    bitmap, count, _ = jax.lax.while_loop(cond, body, state0)
    return bitmap, count
