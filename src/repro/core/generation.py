"""Candidate-pattern generation (paper Algorithms 2–4).

FLEXIS generation: merge pairs of frequent (k−1)-vertex patterns sharing an
isomorphic (k−2)-vertex core graph Γ, under every automorphism of Γ; cliques
additionally require a third supporting pattern (Lemma 3.5), which we enforce
through the paper's own post-processing rule — *every connected (k−1)-vertex
subpattern of a candidate clique must be frequent* — the two are equivalent
(the third core graph exists iff the corresponding (k−1)-subclique is
frequent, see Lemma 3.5's proof).

The edge-extension baseline (GraMi/T-FSM-style growth) lives here too so the
benchmark harness can compare searched-pattern counts (paper Table 2).

Everything in this module is host-side numpy: pattern sets are small (control
plane).  The device plane is `matcher.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .pattern import Pattern
from .canonical import (
    automorphisms,
    canonical_key,
    dedupe_patterns,
    find_isomorphism,
)

__all__ = [
    "CoreGraph",
    "core_graphs",
    "core_groups",
    "generate_new_patterns",
    "edge_extension_candidates",
    "size2_patterns",
]


@dataclasses.dataclass(frozen=True)
class CoreGraph:
    """A pattern with one vertex disconnected (the *marked* vertex).

    gamma:       the (k−2)-vertex remainder Γ (marked vertex removed).
    attach_out:  (k−2,) bool — marked → Γ[i] edges.
    attach_in:   (k−2,) bool — Γ[i] → marked edges.
    marked_label: label of the marked vertex.
    parent:      the pattern this core graph came from.
    is_clique_parent: parent pattern is a clique (undirected sense).
    """

    gamma: Pattern
    attach_out: np.ndarray
    attach_in: np.ndarray
    marked_label: int
    parent: Pattern
    is_clique_parent: bool

    def remapped(self, perm: np.ndarray) -> "CoreGraph":
        """Express the attachment w.r.t. gamma.permuted(perm).

        perm maps our Γ vertex i to position perm[i] in the target Γ, so the
        target's attach vectors gather through the inverse.
        """
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0])
        return CoreGraph(
            gamma=self.gamma.permuted(perm),
            attach_out=self.attach_out[inv],
            attach_in=self.attach_in[inv],
            marked_label=self.marked_label,
            parent=self.parent,
            is_clique_parent=self.is_clique_parent,
        )


def core_graphs(pat: Pattern) -> List[CoreGraph]:
    """All k core graphs of `pat` (one per marked vertex).

    Γ may be *disconnected* — and must be kept: Lemma 3.4 reconstructs e.g. a
    4-cycle from two 3-paths whose shared Γ is a pair of isolated vertices
    (the two non-adjacent cycle vertices removed).  Disconnected *candidates*
    are filtered after the merge instead.
    """
    out: List[CoreGraph] = []
    is_clq = pat.is_clique()
    for v in range(pat.k):
        gamma = pat.remove_vertex(v)
        keep = [i for i in range(pat.k) if i != v]
        out.append(
            CoreGraph(
                gamma=gamma,
                attach_out=pat.adj[v, keep].copy(),
                attach_in=pat.adj[keep, v].copy(),
                marked_label=int(pat.labels[v]),
                parent=pat,
                is_clique_parent=is_clq,
            )
        )
    return out


def core_groups(patterns: Sequence[Pattern]) -> Dict[Tuple, List[CoreGraph]]:
    """Group core graphs by canonical key of Γ, remapping each onto the
    group representative's Γ so attachments are directly comparable."""
    groups: Dict[Tuple, List[CoreGraph]] = {}
    reps: Dict[Tuple, Pattern] = {}
    for pat in patterns:
        for cg in core_graphs(pat):
            key = canonical_key(cg.gamma)
            if key not in groups:
                groups[key] = [cg]
                reps[key] = cg.gamma
            else:
                perm = find_isomorphism(cg.gamma, reps[key])
                assert perm is not None, "canonical key collision"
                groups[key].append(cg.remapped(perm))
    return groups


def _merge(c1: CoreGraph, c2: CoreGraph, alpha: np.ndarray) -> Pattern:
    """MERGE (Alg 2 line 8): Γ + marked(C1) + α-twisted marked(C2).

    Both core graphs must already be expressed w.r.t. the same Γ. α is an
    automorphism of Γ applied to C2's attachment.
    """
    g = c1.gamma
    m = g.k
    adj = np.zeros((m + 2, m + 2), dtype=bool)
    adj[:m, :m] = g.adj
    # vertex m   = marked of c1
    adj[m, :m] = c1.attach_out
    adj[:m, m] = c1.attach_in
    # vertex m+1 = marked of c2, attachment twisted by α:
    # α maps Γ vertex i -> α[i]; c2's marked connected to i now connects to α[i]
    a_out = np.zeros(m, dtype=bool)
    a_in = np.zeros(m, dtype=bool)
    a_out[alpha] = c2.attach_out
    a_in[alpha] = c2.attach_in
    adj[m + 1, :m] = a_out
    adj[:m, m + 1] = a_in
    labels = np.concatenate([g.labels, [c1.marked_label, c2.marked_label]])
    return Pattern(adj, labels.astype(np.int32))


def _connected_subpatterns(pat: Pattern) -> List[Pattern]:
    subs = []
    for v in range(pat.k):
        sp = pat.remove_vertex(v)
        if sp.is_connected():
            subs.append(sp)
    return subs


def _clique_completions(
    merged: Pattern, frequent_keys: set
) -> List[Pattern]:
    """GENERATECLIQUES (Alg 4) via the paper's post-processing rule.

    `merged` is a k-pattern whose last two vertices (the two marked vertices)
    are not joined.  If every other pair is joined, adding a directed edge
    between them can complete a clique.  We enumerate the three directed
    closures and keep those whose connected (k−1)-subpatterns are *all*
    frequent — the paper's final check, equivalent to finding the third
    supporting core graph (Lemma 3.5).
    """
    k = pat_k = merged.k
    u, v = pat_k - 2, pat_k - 1
    und = merged.undirected_adj()
    # all pairs except (u, v) must already be joined
    need = ~(und | np.eye(k, dtype=bool))
    need[u, v] = need[v, u] = False
    if np.any(need):
        return []
    out = []
    for e_uv, e_vu in ((True, False), (False, True), (True, True)):
        adj = merged.adj.copy()
        adj[u, v] = e_uv
        adj[v, u] = e_vu
        cand = Pattern(adj, merged.labels)
        if all(canonical_key(sp) in frequent_keys for sp in _connected_subpatterns(cand)):
            out.append(cand)
    return out


def generate_new_patterns(
    frequent: Sequence[Pattern],
    *,
    downward_closure: bool = True,
) -> List[Pattern]:
    """GENERATENEWPATTERNS (Algorithm 2): all k-vertex candidates from the
    frequent (k−1)-vertex set.

    downward_closure: additionally require every connected (k−1)-subpattern
    of a *non-clique* candidate to be frequent.  The paper proves this prunes
    no frequent pattern (Theorem 3.6's anti-monotone argument); it is always
    applied to cliques (part of Alg 4) and we default it on everywhere.
    """
    if not frequent:
        return []
    frequent_keys = {canonical_key(p) for p in frequent}
    groups = core_groups(frequent)
    out: List[Pattern] = []
    for key, cgs in groups.items():
        if not cgs:
            continue
        auts = automorphisms(cgs[0].gamma)
        for i in range(len(cgs)):
            for j in range(i, len(cgs)):
                c1, c2 = cgs[i], cgs[j]
                # dedupe attachment twists: distinct α images only
                seen_twists = set()
                for alpha in auts:
                    tw = (c2.attach_out[np.argsort(alpha)].tobytes(),
                          c2.attach_in[np.argsort(alpha)].tobytes())
                    if tw in seen_twists:
                        continue
                    seen_twists.add(tw)
                    cand = _merge(c1, c2, alpha)
                    if not cand.is_connected():
                        continue
                    out.append(cand)
                    if c1.is_clique_parent and c2.is_clique_parent:
                        out.extend(_clique_completions(cand, frequent_keys))
    out = dedupe_patterns(out)
    if downward_closure:
        out = [
            p
            for p in out
            if all(canonical_key(sp) in frequent_keys for sp in _connected_subpatterns(p))
        ]
    return out


# ---------------------------------------------------------------------------
# Baseline: edge-extension generation (GraMi / T-FSM growth rule)
# ---------------------------------------------------------------------------

def size2_patterns(labels: Iterable[int]) -> List[Pattern]:
    """All directed 2-vertex candidates over a label set: ℓ1→ℓ2 and ℓ1⇄ℓ2."""
    labs = sorted(set(int(l) for l in labels))
    out: List[Pattern] = []
    for a in labs:
        for b in labs:
            adj = np.zeros((2, 2), dtype=bool)
            adj[0, 1] = True
            out.append(Pattern(adj.copy(), np.array([a, b], np.int32)))
            adj[1, 0] = True
            out.append(Pattern(adj, np.array([a, b], np.int32)))
    return dedupe_patterns(out)


def edge_extension_candidates(
    frequent: Sequence[Pattern],
    vertex_labels: Sequence[int],
    *,
    max_k: int | None = None,
) -> List[Pattern]:
    """Grow each frequent pattern by exactly one edge (GraMi-style).

    Two growth moves: (a) attach a brand-new vertex (any label, either
    direction) to any existing vertex; (b) close an edge between an existing
    non-adjacent (directed) pair.  Candidates are deduped canonically — the
    redundancy-elimination cost this incurs is precisely the overhead the
    paper's merging strategy avoids (§1, §3.1.2).
    """
    labs = sorted(set(int(l) for l in vertex_labels))
    out: List[Pattern] = []
    for pat in frequent:
        if max_k is None or pat.k < max_k:
            for v in range(pat.k):
                for lab in labs:
                    out.append(pat.add_vertex(lab, out_to=[v]))
                    out.append(pat.add_vertex(lab, in_from=[v]))
        for i in range(pat.k):
            for j in range(pat.k):
                if i != j and not pat.adj[i, j]:
                    out.append(pat.with_edge(i, j))
    return dedupe_patterns(out)
