"""Data-parallel subgraph matcher — the TPU-native replacement for VF3-Light.

VF3-Light enumerates embeddings with DFS backtracking; here a *frontier
table* of partial embeddings (a dense ``(cap, k)`` int32 array) advances one
pattern vertex per level, in lockstep:

  level i:  anchors  = emb[:, anchor_pos[i]]
            cands    = chunked gather of the anchors' CSR adjacency rows
            mask     = label ∧ degree ∧ injectivity ∧ edge-checks
            emb'     = cumsum-compaction of the masked (cap × chunk) grid

Edge-existence checks run a fixed-depth branchless binary search over each
CSR row (no hash tables, no int64 keys — int32 only, TPU-friendly).

Everything is static-shaped; overflow beyond ``cap`` is *counted* and
surfaced, never silently dropped.  The host drives root *blocks* through
``match_block`` and owns early termination (τ reached) — device code is one
jit-compiled function per pattern size k, reused across all patterns of that
size (plans are data, not static arguments).  Because plans are data,
``match_block`` is also ``vmap``-able over a leading pattern axis — the
batched data plane (``core/batched.py``) runs a whole same-k candidate
level as one program, and ``core/distributed.py`` composes that axis with
root sharding under ``shard_map``.

Two expansion planes implement the level step (``MatchConfig.expansion``):

  * ``"xla"`` — the reference pipeline below (`_expand_level`): one XLA op
    chain per chunk, with the candidate grid and frontier tables spilling
    to HBM between stages.  Optionally two-phase (cheap filters → compact
    → bisect survivors only).
  * ``"pallas"`` — the fused kernel (``repro.kernels.frontier_expand``):
    the whole level runs as one Pallas program with the frontier tile and
    CSR arrays VMEM-resident across chunks.  Bit-identical to the
    single-phase XLA pipeline (survivor order included); under ``vmap``
    the pattern axis becomes a kernel-grid dimension, so a batched level
    is still one launch.  See ``docs/kernels.md`` for the interpret-mode
    fallback rule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DataGraph, DeviceGraph
from .plan import PatternPlan

__all__ = ["MatchConfig", "match_block", "edge_exists", "device_graph_tuple",
           "transient_match_bytes"]


# Register the graph/plan dataclasses as pytrees so they pass through jit
# without recompilation per pattern.
def _dg_flatten(g: DeviceGraph):
    return (
        (g.labels, g.out_indptr, g.out_indices, g.in_indptr, g.in_indices),
        g.n,
    )


def _dg_unflatten(n, children):
    return DeviceGraph(n, *children)


jax.tree_util.register_pytree_node(DeviceGraph, _dg_flatten, _dg_unflatten)


def _plan_flatten(p: PatternPlan):
    arrays = (
        p.root_label,
        p.root_min_out,
        p.root_min_in,
        p.anchor_pos,
        p.anchor_out,
        p.cand_label,
        p.min_out,
        p.min_in,
        p.check_out,
        p.check_in,
    )
    return arrays, (p.k, p.order)


def _plan_unflatten(aux, children):
    k, order = aux
    return PatternPlan(k, *children, order=order)


jax.tree_util.register_pytree_node(PatternPlan, _plan_flatten, _plan_unflatten)


@dataclasses.dataclass(frozen=True)
class MatchConfig:
    """Static matcher geometry (one jit cache entry per distinct config + k).

    Hashable & frozen — it is a ``static_argnames`` entry of ``match_block``,
    so every distinct config value is a separate compiled program.
    """

    cap: int = 8192          # frontier capacity (embeddings per level)
    root_block: int = 4096   # roots processed per host iteration
    chunk: int = 64          # neighbors gathered per expansion chunk
    max_chunks: int = 8      # ceil(max_degree / chunk)
    bisect_iters: int = 12   # ceil(log2(max_degree + 1))
    # two-phase expansion (EXPERIMENTS.md §Perf, flexis-mining cell): run the
    # cheap filters (label/degree/injectivity) on the full (cap × chunk)
    # grid, compact survivors, and run the edge-existence bisection only on
    # the compacted lanes — label selectivity pays for the extra compaction.
    # Only meaningful on the "xla" plane; the fused kernel keeps the grid
    # VMEM-resident, which is what two-phase's HBM-traffic cut approximates.
    two_phase: bool = False
    # expansion plane: "xla" = per-chunk op pipeline (reference), "pallas" =
    # fused per-level kernel (repro.kernels.frontier_expand), bit-identical
    # to the single-phase xla pipeline.
    expansion: str = "xla"
    # run the Pallas kernel in interpret mode (required off-TPU; this
    # container is CPU-only).  Ignored when expansion == "xla".
    pallas_interpret: bool = True

    def __post_init__(self):
        if self.expansion not in ("xla", "pallas"):
            raise ValueError('expansion must be "xla" or "pallas"')
        # two_phase is an xla-plane knob; the fused kernel is single-phase by
        # construction.  Normalize so a pallas config never *claims* two-phase
        # semantics (truncation content under overflow differs between the
        # two-phase pipeline and the single-phase planes — always flagged via
        # `overflowed`, but configs should say what they run).
        if self.expansion == "pallas" and self.two_phase:
            object.__setattr__(self, "two_phase", False)
        # pallas_interpret is a pallas-plane knob; canonicalize it on the
        # xla plane so configs that run the identical program hash equal
        # (MatchConfig keys both the match_block jit cache and the batched
        # step-program cache).
        if self.expansion == "xla" and not self.pallas_interpret:
            object.__setattr__(self, "pallas_interpret", True)

    @classmethod
    def for_graph(cls, g: DataGraph, *, cap: int = 8192, root_block: int = 4096,
                  chunk: int = 64, expansion: str = "xla") -> "MatchConfig":
        """Right-size the geometry to the graph: the frontier capacity and
        root blocks never usefully exceed the graph scale, and the chunk
        width never usefully exceeds the max degree."""
        max_deg = max(g.max_out_degree, g.max_in_degree, 1)
        chunk = int(min(chunk, 1 << int(np.ceil(np.log2(max_deg + 1)))))
        root_block = int(min(root_block, max(128, 1 << int(np.ceil(np.log2(g.n))))))
        cap = int(min(cap, max(1024, 1 << int(np.ceil(np.log2(g.n_edges + 1))))))
        return cls(
            cap=cap,
            root_block=root_block,
            chunk=chunk,
            max_chunks=max(1, -(-max_deg // chunk)),
            bisect_iters=max(2, int(np.ceil(np.log2(max_deg + 1))) + 1),
            # measured 8–9× matcher speedup at identical results on both
            # label-rich and label-poor graphs (EXPERIMENTS.md §Perf cell 3)
            two_phase=True,
            expansion=expansion,
        )


def transient_match_bytes(cfg: MatchConfig, k: int) -> int:
    """Transient device footprint of one match step for ONE pattern (bytes).

    Counts the two (cap, k) int32 frontier tables plus the
    (cap × chunk) candidate-expansion grid with its per-lane intermediates
    (≈ k + 8 int32 each: candidate rows, mask/cumsum/dest lanes).

    This is a *per-pattern* number: the batched plane runs P patterns per
    program (leading pattern axis), so its peak transient footprint is
    ``bucket_size(P) · transient_match_bytes(cfg, k)`` — exactly how
    ``core/batched.py`` accounts it, keeping sequential and batched
    ``peak_device_bytes`` telemetry consistent.  On the "pallas" expansion
    plane the same buffers exist but live in VMEM scratch for the duration
    of a level instead of spilling to HBM between pipeline stages.
    """
    emb = cfg.cap * k * 4
    return emb * 2 + cfg.cap * cfg.chunk * (k + 8) * 4


def edge_exists(indptr, indices, u, v, n_iters: int):
    """Branchless bounded binary search: is v in sorted indices[indptr[u]:indptr[u+1]]?

    indptr: (n+1,) int32 CSR row pointers; indices: (E,) int32 sorted within
    each row.  u, v: int32 arrays (broadcast-compatible); entries must be
    pre-clipped to [0, n).  n_iters must be ≥ ceil(log2(max_degree + 1)).
    Returns a bool array of the broadcast shape.  Pure dataflow (no host
    control), so it runs unchanged inside jit, vmap, shard_map, and the
    Pallas kernel body.
    """
    lo = indptr[u].astype(jnp.int32)
    hi = (indptr[u + 1]).astype(jnp.int32)
    # invariant: answer position (if any) in [lo, hi)
    for _ in range(n_iters):
        mid = (lo + hi) >> 1
        mid_safe = jnp.clip(mid, 0, indices.shape[0] - 1)
        go_right = (indices[mid_safe] < v) & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    lo_safe = jnp.clip(lo, 0, indices.shape[0] - 1)
    found = (lo < indptr[u + 1].astype(jnp.int32)) & (indices[lo_safe] == v)
    return found


def device_graph_tuple(g: DataGraph) -> DeviceGraph:
    """Upload a host `DataGraph` as the int32 jnp mirror the matcher reads.

    Returns a `DeviceGraph` pytree: labels (n,), out/in_indptr (n+1,),
    out/in_indices (E,) — all int32; edgeless graphs get 1-element sentinel
    index arrays so gathers stay well-formed (see `DeviceGraph.from_host`).
    """
    return DeviceGraph.from_host(g)


def _degrees(indptr, verts):
    return (indptr[verts + 1] - indptr[verts]).astype(jnp.int32)


def _init_roots(g: DeviceGraph, plan: PatternPlan, block_start, cfg: MatchConfig):
    """Root frontier for one block: vertices in [block_start, block_start+R)
    matching the root's label + degree filters, compacted into (cap, k)."""
    R, cap, k = cfg.root_block, cfg.cap, plan.k
    verts = block_start + jnp.arange(R, dtype=jnp.int32)
    in_range = verts < g.n
    safe = jnp.clip(verts, 0, g.n - 1)
    ok = (
        in_range
        & (g.labels[safe] == plan.root_label)
        & (_degrees(g.out_indptr, safe) >= plan.root_min_out)
        & (_degrees(g.in_indptr, safe) >= plan.root_min_in)
    )
    pos = jnp.cumsum(ok) - 1
    dest = jnp.where(ok & (pos < cap), pos, cap)
    emb = jnp.full((cap + 1, k), -1, dtype=jnp.int32)
    emb = emb.at[dest, 0].set(safe, mode="drop")
    count = jnp.minimum(ok.sum(), cap).astype(jnp.int32)
    return emb[:cap], count


def _expand_level(g: DeviceGraph, plan: PatternPlan, emb, count, level: int,
                  cfg: MatchConfig):
    """Extend every partial embedding by pattern-order vertex `level`.

    emb: (cap, k) int32 frontier (columns ≥ level are -1); count: () int32
    valid rows.  Returns (out_emb (cap, k) int32, out_count () int32,
    found () int32, overflowed () bool); survivors are packed in
    (chunk, row, position) order — the order the greedy-mIS metric consumes.
    Dispatches to the fused Pallas kernel when cfg.expansion == "pallas"
    (bit-identical to the single-phase pipeline below).
    """
    if cfg.expansion == "pallas":
        from repro.kernels.frontier_expand.ops import frontier_expand_level

        return frontier_expand_level(g, plan, emb, count, level, cfg)
    cap, C, k = cfg.cap, cfg.chunk, plan.k
    i = level  # python int (static): column being filled
    n_idx = g.out_indices.shape[0]
    # concatenated adjacency so out/in selection is an offset, not two gathers
    indices_cat = jnp.concatenate([g.out_indices, g.in_indices])

    anchor_pos = plan.anchor_pos[i]
    use_out = plan.anchor_out[i]
    anchors = jnp.take_along_axis(emb, jnp.full((cap, 1), anchor_pos, jnp.int32), axis=1)[:, 0]
    anchors_safe = jnp.clip(anchors, 0, g.n - 1)
    out_start = g.out_indptr[anchors_safe].astype(jnp.int32)
    in_start = g.in_indptr[anchors_safe].astype(jnp.int32)
    start = jnp.where(use_out, out_start, in_start + n_idx)
    deg = jnp.where(
        use_out,
        _degrees(g.out_indptr, anchors_safe),
        _degrees(g.in_indptr, anchors_safe),
    )
    row_valid = jnp.arange(cap, dtype=jnp.int32) < count

    out_emb0 = jnp.full((cap + 1, k), -1, dtype=jnp.int32)

    def _cheap_mask(cand, cand_safe, in_deg_range):
        mask = row_valid[:, None] & in_deg_range
        mask &= g.labels[cand_safe] == plan.cand_label[i]
        mask &= _degrees(g.out_indptr, cand_safe) >= plan.min_out[i]
        mask &= _degrees(g.in_indptr, cand_safe) >= plan.min_in[i]
        for j in range(i):
            mask &= cand != emb[:, j][:, None]  # injectivity
        return mask

    def _edge_checks(cand_safe, prev_rows):
        """prev_rows: (..., k) prefix columns aligned with cand_safe."""
        ok = jnp.ones(cand_safe.shape, bool)
        for j in range(i):  # static unroll over prefix
            prev_safe = jnp.clip(prev_rows[..., j], 0, g.n - 1)
            co = plan.check_out[i, j]
            ci = plan.check_in[i, j]
            ok_out = edge_exists(g.out_indptr, g.out_indices, cand_safe,
                                 prev_safe, cfg.bisect_iters)
            ok_in = edge_exists(g.out_indptr, g.out_indices, prev_safe,
                                cand_safe, cfg.bisect_iters)
            ok &= jnp.where(co, ok_out, True)
            ok &= jnp.where(ci, ok_in, True)
        return ok

    def chunk_body(c, carry):
        out_emb, out_count, found, ovf = carry
        off = c * C + jnp.arange(C, dtype=jnp.int32)[None, :]          # (1, C)
        idx = start[:, None] + off                                     # (cap, C)
        in_deg_range = off < deg[:, None]
        cand = indices_cat[jnp.clip(idx, 0, indices_cat.shape[0] - 1)]  # (cap, C)
        cand_safe = jnp.clip(cand, 0, g.n - 1)
        mask = _cheap_mask(cand, cand_safe, in_deg_range)
        src_row_grid = jnp.arange(cap * C, dtype=jnp.int32) // C

        if cfg.two_phase and i > 0:
            # compact cheap-filter survivors, bisect only those lanes
            flat = mask.reshape(-1)
            pos1 = jnp.cumsum(flat).astype(jnp.int32) - 1
            dest1 = jnp.where(flat & (pos1 < cap), pos1, cap)
            cand_buf = jnp.zeros((cap + 1,), jnp.int32).at[dest1].set(
                cand_safe.reshape(-1), mode="drop")[:cap]
            row_buf = jnp.zeros((cap + 1,), jnp.int32).at[dest1].set(
                src_row_grid, mode="drop")[:cap]
            n_phase1 = flat.sum().astype(jnp.int32)
            n_mid = jnp.minimum(n_phase1, cap)
            mid_valid = jnp.arange(cap, dtype=jnp.int32) < n_mid
            prev_rows = emb[row_buf]                                   # (cap, k)
            ok = mid_valid & _edge_checks(cand_buf, prev_rows)
            n_new = ok.sum().astype(jnp.int32)
            pos = jnp.cumsum(ok).astype(jnp.int32) - 1 + out_count
            dest = jnp.where(ok & (pos < cap), pos, cap)
            rows = prev_rows.at[:, i].set(cand_buf)
            out_emb = out_emb.at[dest].set(rows, mode="drop")
            ovf |= n_phase1 > cap  # phase-1 drop: results may be incomplete
            return (out_emb, jnp.minimum(out_count + n_new, cap),
                    found + n_new, ovf)

        mask &= _edge_checks(cand_safe, emb[:, None, :])
        flat_mask = mask.reshape(-1)
        n_new = flat_mask.sum().astype(jnp.int32)
        pos = jnp.cumsum(flat_mask).astype(jnp.int32) - 1 + out_count
        dest = jnp.where(flat_mask & (pos < cap), pos, cap)
        rows = emb[src_row_grid].at[:, i].set(cand.reshape(-1))
        out_emb = out_emb.at[dest].set(rows, mode="drop")
        return (out_emb, jnp.minimum(out_count + n_new, cap),
                found + n_new, ovf)

    out_emb, out_count, found, ovf = jax.lax.fori_loop(
        0, cfg.max_chunks, chunk_body,
        (out_emb0, jnp.int32(0), jnp.int32(0), jnp.bool_(False)),
    )
    return out_emb[:cap], out_count, found, ovf


@functools.partial(jax.jit, static_argnames=("cfg",))
def match_block(g: DeviceGraph, plan: PatternPlan, block_start, cfg: MatchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
                           jnp.ndarray]:
    """Enumerate embeddings rooted in one vertex block.

    Args:
      g:    DeviceGraph pytree (int32 arrays; see `device_graph_tuple`).
      plan: PatternPlan pytree — *data*, so one compiled program serves all
            patterns of size k.  A leading pattern axis on every plan field
            (from `plan.stack_plans`) makes this function `vmap`-able; with
            cfg.expansion == "pallas" that axis becomes a kernel-grid
            dimension rather than a per-pattern kernel re-entry.
      block_start: () int32 — first root vertex of this block.
      cfg:  static MatchConfig (hashable; keys the jit cache with k).

    Returns (emb, count, found, overflowed, peak):
      emb:    (cap, k) int32 — embeddings in pattern-order columns, row-major
              in (root, discovery) order (so row index = greedy priority);
              invalid rows are -1-filled.
      count:  () int32 — rows of `emb` that are valid (≤ cap).
      found:  () int32 — embeddings enumerated in the last level before
              capacity clipping.
      overflowed: () bool — some level produced more than `cap` rows (results
              are truncated, never silently wrong).
      peak:   () int32 — max frontier occupancy over all levels (root level
              included, post-clip, so ≤ cap).  This is the observed-occupancy
              signal the execution planner's per-level ``cap`` right-sizing
              consumes (`core/planner.py`); when `overflowed` is set the true
              need exceeded `cap` and `peak` is only a lower bound.
    """
    emb, count = _init_roots(g, plan, block_start, cfg)
    found = count
    peak = count
    overflowed = jnp.bool_(False)
    for level in range(1, plan.k):
        emb, count, lvl_found, lvl_ovf = _expand_level(
            g, plan, emb, count, level, cfg)
        overflowed |= lvl_ovf | (lvl_found > cfg.cap)
        found = lvl_found
        peak = jnp.maximum(peak, count)
    return emb, count, found, overflowed, peak
