"""Data-parallel subgraph matcher — the TPU-native replacement for VF3-Light.

VF3-Light enumerates embeddings with DFS backtracking; here a *frontier
table* of partial embeddings (a dense ``(cap, k)`` int32 array) advances one
pattern vertex per level, in lockstep:

  level i:  anchors  = emb[:, anchor_pos[i]]
            cands    = chunked gather of the anchors' CSR adjacency rows
            mask     = label ∧ degree ∧ injectivity ∧ edge-checks
            emb'     = cumsum-compaction of the masked (cap × chunk) grid

Edge-existence checks run a fixed-depth branchless binary search over each
CSR row (no hash tables, no int64 keys — int32 only, TPU-friendly).

Everything is static-shaped; overflow beyond ``cap`` is *counted* and
surfaced, never silently dropped.  The host drives root *blocks* through
``match_block`` and owns early termination (τ reached) — device code is one
jit-compiled function per pattern size k, reused across all patterns of that
size (plans are data, not static arguments).  Because plans are data,
``match_block`` is also ``vmap``-able over a leading pattern axis — the
batched data plane (``core/batched.py``) runs a whole same-k candidate
level as one program, and ``core/distributed.py`` composes that axis with
root sharding under ``shard_map``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DataGraph, DeviceGraph
from .plan import PatternPlan

__all__ = ["MatchConfig", "match_block", "edge_exists", "device_graph_tuple",
           "transient_match_bytes"]


# Register the graph/plan dataclasses as pytrees so they pass through jit
# without recompilation per pattern.
def _dg_flatten(g: DeviceGraph):
    return (
        (g.labels, g.out_indptr, g.out_indices, g.in_indptr, g.in_indices),
        g.n,
    )


def _dg_unflatten(n, children):
    return DeviceGraph(n, *children)


jax.tree_util.register_pytree_node(DeviceGraph, _dg_flatten, _dg_unflatten)


def _plan_flatten(p: PatternPlan):
    arrays = (
        p.root_label,
        p.root_min_out,
        p.root_min_in,
        p.anchor_pos,
        p.anchor_out,
        p.cand_label,
        p.min_out,
        p.min_in,
        p.check_out,
        p.check_in,
    )
    return arrays, (p.k, p.order)


def _plan_unflatten(aux, children):
    k, order = aux
    return PatternPlan(k, *children, order=order)


jax.tree_util.register_pytree_node(PatternPlan, _plan_flatten, _plan_unflatten)


@dataclasses.dataclass(frozen=True)
class MatchConfig:
    """Static matcher geometry (one jit cache entry per distinct config + k)."""

    cap: int = 8192          # frontier capacity (embeddings per level)
    root_block: int = 4096   # roots processed per host iteration
    chunk: int = 64          # neighbors gathered per expansion chunk
    max_chunks: int = 8      # ceil(max_degree / chunk)
    bisect_iters: int = 12   # ceil(log2(max_degree + 1))
    # two-phase expansion (EXPERIMENTS.md §Perf, flexis-mining cell): run the
    # cheap filters (label/degree/injectivity) on the full (cap × chunk)
    # grid, compact survivors, and run the edge-existence bisection only on
    # the compacted lanes — label selectivity pays for the extra compaction.
    two_phase: bool = False

    @classmethod
    def for_graph(cls, g: DataGraph, *, cap: int = 8192, root_block: int = 4096,
                  chunk: int = 64) -> "MatchConfig":
        """Right-size the geometry to the graph: the frontier capacity and
        root blocks never usefully exceed the graph scale, and the chunk
        width never usefully exceeds the max degree."""
        max_deg = max(g.max_out_degree, g.max_in_degree, 1)
        chunk = int(min(chunk, 1 << int(np.ceil(np.log2(max_deg + 1)))))
        root_block = int(min(root_block, max(128, 1 << int(np.ceil(np.log2(g.n))))))
        cap = int(min(cap, max(1024, 1 << int(np.ceil(np.log2(g.n_edges + 1))))))
        return cls(
            cap=cap,
            root_block=root_block,
            chunk=chunk,
            max_chunks=max(1, -(-max_deg // chunk)),
            bisect_iters=max(2, int(np.ceil(np.log2(max_deg + 1))) + 1),
            # measured 8–9× matcher speedup at identical results on both
            # label-rich and label-poor graphs (EXPERIMENTS.md §Perf cell 3)
            two_phase=True,
        )


def transient_match_bytes(cfg: MatchConfig, k: int) -> int:
    """Per-pattern transient device footprint of one match step (telemetry):
    two frontier tables plus the candidate-expansion grid.  Shared by the
    sequential and batched planes so their peak_device_bytes agree."""
    emb = cfg.cap * k * 4
    return emb * 2 + cfg.cap * cfg.chunk * (k + 8) * 4


def edge_exists(indptr, indices, u, v, n_iters: int):
    """Branchless bounded binary search: is v in sorted indices[indptr[u]:indptr[u+1]]?

    u, v: int32 arrays (broadcast-compatible). Returns bool array.
    """
    lo = indptr[u].astype(jnp.int32)
    hi = (indptr[u + 1]).astype(jnp.int32)
    # invariant: answer position (if any) in [lo, hi)
    for _ in range(n_iters):
        mid = (lo + hi) >> 1
        mid_safe = jnp.clip(mid, 0, indices.shape[0] - 1)
        go_right = (indices[mid_safe] < v) & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    lo_safe = jnp.clip(lo, 0, indices.shape[0] - 1)
    found = (lo < indptr[u + 1].astype(jnp.int32)) & (indices[lo_safe] == v)
    return found


def device_graph_tuple(g: DataGraph) -> DeviceGraph:
    return DeviceGraph.from_host(g)


def _degrees(indptr, verts):
    return (indptr[verts + 1] - indptr[verts]).astype(jnp.int32)


def _init_roots(g: DeviceGraph, plan: PatternPlan, block_start, cfg: MatchConfig):
    """Root frontier for one block: vertices in [block_start, block_start+R)
    matching the root's label + degree filters, compacted into (cap, k)."""
    R, cap, k = cfg.root_block, cfg.cap, plan.k
    verts = block_start + jnp.arange(R, dtype=jnp.int32)
    in_range = verts < g.n
    safe = jnp.clip(verts, 0, g.n - 1)
    ok = (
        in_range
        & (g.labels[safe] == plan.root_label)
        & (_degrees(g.out_indptr, safe) >= plan.root_min_out)
        & (_degrees(g.in_indptr, safe) >= plan.root_min_in)
    )
    pos = jnp.cumsum(ok) - 1
    dest = jnp.where(ok & (pos < cap), pos, cap)
    emb = jnp.full((cap + 1, k), -1, dtype=jnp.int32)
    emb = emb.at[dest, 0].set(safe, mode="drop")
    count = jnp.minimum(ok.sum(), cap).astype(jnp.int32)
    return emb[:cap], count


def _expand_level(g: DeviceGraph, plan: PatternPlan, emb, count, level: int,
                  cfg: MatchConfig):
    """Extend every partial embedding by pattern-order vertex `level`."""
    cap, C, k = cfg.cap, cfg.chunk, plan.k
    i = level  # python int (static): column being filled
    n_idx = g.out_indices.shape[0]
    # concatenated adjacency so out/in selection is an offset, not two gathers
    indices_cat = jnp.concatenate([g.out_indices, g.in_indices])

    anchor_pos = plan.anchor_pos[i]
    use_out = plan.anchor_out[i]
    anchors = jnp.take_along_axis(emb, jnp.full((cap, 1), anchor_pos, jnp.int32), axis=1)[:, 0]
    anchors_safe = jnp.clip(anchors, 0, g.n - 1)
    out_start = g.out_indptr[anchors_safe].astype(jnp.int32)
    in_start = g.in_indptr[anchors_safe].astype(jnp.int32)
    start = jnp.where(use_out, out_start, in_start + n_idx)
    deg = jnp.where(
        use_out,
        _degrees(g.out_indptr, anchors_safe),
        _degrees(g.in_indptr, anchors_safe),
    )
    row_valid = jnp.arange(cap, dtype=jnp.int32) < count

    out_emb0 = jnp.full((cap + 1, k), -1, dtype=jnp.int32)

    def _cheap_mask(cand, cand_safe, in_deg_range):
        mask = row_valid[:, None] & in_deg_range
        mask &= g.labels[cand_safe] == plan.cand_label[i]
        mask &= _degrees(g.out_indptr, cand_safe) >= plan.min_out[i]
        mask &= _degrees(g.in_indptr, cand_safe) >= plan.min_in[i]
        for j in range(i):
            mask &= cand != emb[:, j][:, None]  # injectivity
        return mask

    def _edge_checks(cand_safe, prev_rows):
        """prev_rows: (..., k) prefix columns aligned with cand_safe."""
        ok = jnp.ones(cand_safe.shape, bool)
        for j in range(i):  # static unroll over prefix
            prev_safe = jnp.clip(prev_rows[..., j], 0, g.n - 1)
            co = plan.check_out[i, j]
            ci = plan.check_in[i, j]
            ok_out = edge_exists(g.out_indptr, g.out_indices, cand_safe,
                                 prev_safe, cfg.bisect_iters)
            ok_in = edge_exists(g.out_indptr, g.out_indices, prev_safe,
                                cand_safe, cfg.bisect_iters)
            ok &= jnp.where(co, ok_out, True)
            ok &= jnp.where(ci, ok_in, True)
        return ok

    def chunk_body(c, carry):
        out_emb, out_count, found, ovf = carry
        off = c * C + jnp.arange(C, dtype=jnp.int32)[None, :]          # (1, C)
        idx = start[:, None] + off                                     # (cap, C)
        in_deg_range = off < deg[:, None]
        cand = indices_cat[jnp.clip(idx, 0, indices_cat.shape[0] - 1)]  # (cap, C)
        cand_safe = jnp.clip(cand, 0, g.n - 1)
        mask = _cheap_mask(cand, cand_safe, in_deg_range)
        src_row_grid = jnp.arange(cap * C, dtype=jnp.int32) // C

        if cfg.two_phase and i > 0:
            # compact cheap-filter survivors, bisect only those lanes
            flat = mask.reshape(-1)
            pos1 = jnp.cumsum(flat).astype(jnp.int32) - 1
            dest1 = jnp.where(flat & (pos1 < cap), pos1, cap)
            cand_buf = jnp.zeros((cap + 1,), jnp.int32).at[dest1].set(
                cand_safe.reshape(-1), mode="drop")[:cap]
            row_buf = jnp.zeros((cap + 1,), jnp.int32).at[dest1].set(
                src_row_grid, mode="drop")[:cap]
            n_phase1 = flat.sum().astype(jnp.int32)
            n_mid = jnp.minimum(n_phase1, cap)
            mid_valid = jnp.arange(cap, dtype=jnp.int32) < n_mid
            prev_rows = emb[row_buf]                                   # (cap, k)
            ok = mid_valid & _edge_checks(cand_buf, prev_rows)
            n_new = ok.sum().astype(jnp.int32)
            pos = jnp.cumsum(ok).astype(jnp.int32) - 1 + out_count
            dest = jnp.where(ok & (pos < cap), pos, cap)
            rows = prev_rows.at[:, i].set(cand_buf)
            out_emb = out_emb.at[dest].set(rows, mode="drop")
            ovf |= n_phase1 > cap  # phase-1 drop: results may be incomplete
            return (out_emb, jnp.minimum(out_count + n_new, cap),
                    found + n_new, ovf)

        mask &= _edge_checks(cand_safe, emb[:, None, :])
        flat_mask = mask.reshape(-1)
        n_new = flat_mask.sum().astype(jnp.int32)
        pos = jnp.cumsum(flat_mask).astype(jnp.int32) - 1 + out_count
        dest = jnp.where(flat_mask & (pos < cap), pos, cap)
        rows = emb[src_row_grid].at[:, i].set(cand.reshape(-1))
        out_emb = out_emb.at[dest].set(rows, mode="drop")
        return (out_emb, jnp.minimum(out_count + n_new, cap),
                found + n_new, ovf)

    out_emb, out_count, found, ovf = jax.lax.fori_loop(
        0, cfg.max_chunks, chunk_body,
        (out_emb0, jnp.int32(0), jnp.int32(0), jnp.bool_(False)),
    )
    return out_emb[:cap], out_count, found, ovf


@functools.partial(jax.jit, static_argnames=("cfg",))
def match_block(g: DeviceGraph, plan: PatternPlan, block_start, cfg: MatchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Enumerate embeddings rooted in one vertex block.

    Returns (emb, count, found, overflowed):
      emb:    (cap, k) int32 — embeddings in pattern-order columns, row-major
              in (root, discovery) order (so row index = greedy priority).
      count:  rows of `emb` that are valid (≤ cap).
      found:  total embeddings enumerated before capacity clipping.
      overflowed: bool — some level produced more than `cap` rows.
    """
    emb, count = _init_roots(g, plan, block_start, cfg)
    found = count
    overflowed = jnp.bool_(False)
    for level in range(1, plan.k):
        emb, count, lvl_found, lvl_ovf = _expand_level(
            g, plan, emb, count, level, cfg)
        overflowed |= lvl_ovf | (lvl_found > cfg.cap)
        found = lvl_found
    return emb, count, found, overflowed
