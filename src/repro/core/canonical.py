"""Canonical forms and automorphisms for tiny pattern graphs.

The paper uses the Bliss library. Our patterns are at most ~8 vertices, so an
exact, dependency-free, *vectorized* brute force over all k! permutations is
both simpler and fast enough (8! = 40320 — a single batched numpy pass).

The canonical key of a pattern is the lexicographically smallest
(labels, adjacency-bits) tuple over every relabeling that is consistent with
a label-preserving permutation.  Two patterns are isomorphic iff their keys
are equal.  The automorphism group is the set of permutations mapping a
pattern onto itself.

Permutation tables are cached per (k, label-multiset) — label-preserving
permutations only, which prunes k! hard for labeled patterns.
"""
from __future__ import annotations

import functools
import itertools
from typing import List, Tuple

import numpy as np

from .pattern import Pattern

__all__ = [
    "canonical_key",
    "canonical_form",
    "are_isomorphic",
    "automorphisms",
    "dedupe_patterns",
]

_MAX_K = 9


@functools.lru_cache(maxsize=None)
def _all_perms(k: int) -> np.ndarray:
    if k > _MAX_K:
        raise ValueError(f"pattern too large for brute-force canonicalization: k={k}")
    return np.array(list(itertools.permutations(range(k))), dtype=np.int64)


def _label_preserving_perms(labels: np.ndarray) -> np.ndarray:
    """All permutations p with labels[p] == labels (vectorized filter)."""
    k = labels.shape[0]
    perms = _all_perms(k)
    # perm p maps vertex i -> position p[i]; label preservation means
    # labels[i] == labels[p[i]] for all i  ⇔  labels[perms] == labels row-wise
    ok = np.all(labels[perms] == labels[None, :], axis=1)
    return perms[ok]


def _apply_perms(pat: Pattern, perms: np.ndarray) -> np.ndarray:
    """Batched pattern.permuted: returns (P, k, k) bool adjacency stack.

    For perm p, new_adj[p[i], p[j]] = adj[i, j]  ⇔  new_adj = adj[inv][:, inv]
    where inv is the inverse permutation.
    """
    k = pat.k
    P = perms.shape[0]
    inv = np.empty_like(perms)
    rows = np.arange(P)[:, None]
    inv[rows, perms] = np.arange(k)[None, :]
    # gather: out[p, a, b] = adj[inv[p, a], inv[p, b]]
    return pat.adj[inv[:, :, None], inv[:, None, :]]


def _bits(adj_stack: np.ndarray) -> np.ndarray:
    """Pack (P, k, k) bool into (P, ceil(k*k/8)) uint8 rows for lexsort."""
    P = adj_stack.shape[0]
    return np.packbits(adj_stack.reshape(P, -1), axis=1)


def canonical_key(pat: Pattern) -> Tuple:
    """Exact canonical key; equal keys ⇔ isomorphic patterns."""
    k = pat.k
    if k == 0:
        return (0, b"", b"")
    # Candidate orderings must sort labels canonically first: relabel by
    # sorted label order, then only label-preserving perms of that base.
    order = np.argsort(pat.labels, kind="stable")
    base = pat.permuted(np.argsort(order))  # vertex i -> rank of i in sorted order
    perms = _label_preserving_perms(base.labels)
    stack = _apply_perms(base, perms)
    bits = _bits(stack)
    # lexicographic min over rows
    best = min(range(bits.shape[0]), key=lambda i: bits[i].tobytes())
    return (k, base.labels.tobytes(), bits[best].tobytes())


def canonical_form(pat: Pattern) -> Pattern:
    """A concrete representative pattern of the canonical key."""
    k = pat.k
    if k == 0:
        return pat
    order = np.argsort(pat.labels, kind="stable")
    base = pat.permuted(np.argsort(order))
    perms = _label_preserving_perms(base.labels)
    stack = _apply_perms(base, perms)
    bits = _bits(stack)
    best = min(range(bits.shape[0]), key=lambda i: bits[i].tobytes())
    return Pattern(stack[best], base.labels)


def are_isomorphic(a: Pattern, b: Pattern) -> bool:
    if a.k != b.k or sorted(a.labels.tolist()) != sorted(b.labels.tolist()):
        return False
    return canonical_key(a) == canonical_key(b)


def automorphisms(pat: Pattern) -> np.ndarray:
    """All permutations mapping the pattern onto itself, (A, k) int64.

    Row 0 is always the identity.
    """
    k = pat.k
    if k == 0:
        return np.zeros((1, 0), dtype=np.int64)
    perms = _label_preserving_perms(pat.labels)
    stack = _apply_perms(pat, perms)
    ok = np.all(stack == pat.adj[None], axis=(1, 2))
    auts = perms[ok]
    # put identity first
    ident = np.all(auts == np.arange(k)[None, :], axis=1)
    order = np.argsort(~ident, kind="stable")
    return auts[order]


def find_isomorphism(a: Pattern, b: Pattern) -> np.ndarray | None:
    """A permutation p with a.permuted(p) == b, or None."""
    if a.k != b.k or sorted(a.labels.tolist()) != sorted(b.labels.tolist()):
        return None
    perms = _all_perms(a.k)
    # need labels_a[i] == labels_b[p[i]]: filter
    ok = np.all(a.labels[None, :] == b.labels[perms], axis=1)
    perms = perms[ok]
    if perms.shape[0] == 0:
        return None
    stack = _apply_perms(a, perms)
    hit = np.all(stack == b.adj[None], axis=(1, 2))
    idx = np.nonzero(hit)[0]
    return perms[idx[0]] if idx.size else None


def dedupe_patterns(patterns: List[Pattern]) -> List[Pattern]:
    """RemoveDuplicates (Alg 2, line 11): keep one pattern per canonical key."""
    seen = {}
    for p in patterns:
        key = canonical_key(p)
        if key not in seen:
            seen[key] = p
    return list(seen.values())
