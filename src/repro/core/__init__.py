"""FLEXIS core — the paper's contribution as a composable JAX module."""
from .graph import DataGraph, DeviceGraph, build_graph
from .pattern import Pattern, pattern_from_edges, paper_fig1
from .canonical import (
    are_isomorphic,
    automorphisms,
    canonical_form,
    canonical_key,
    dedupe_patterns,
)
from .generation import (
    core_graphs,
    core_groups,
    edge_extension_candidates,
    generate_new_patterns,
    size2_patterns,
)
from .health import HealthEvent, RunHealth
from .plan import PatternPlan, make_plan
from .matcher import MatchConfig, match_block
from .planner import (
    CostModel,
    ExecutionPlanner,
    LevelPlan,
    block_degree_stat,
    load_calibration,
    root_block_order,
)
from .sampled import (
    evaluate_level_sampled,
    ht_estimate,
    ht_interval,
    normal_quantile,
    systematic_sample,
)
from .flexis import (
    MiningConfig,
    MiningResult,
    PatternStats,
    evaluate_pattern,
    initial_candidates,
    mine,
    tau_threshold,
)

__all__ = [
    "DataGraph", "DeviceGraph", "build_graph",
    "Pattern", "pattern_from_edges", "paper_fig1",
    "are_isomorphic", "automorphisms", "canonical_form", "canonical_key",
    "dedupe_patterns",
    "core_graphs", "core_groups", "edge_extension_candidates",
    "generate_new_patterns", "size2_patterns",
    "HealthEvent", "RunHealth",
    "PatternPlan", "make_plan", "MatchConfig", "match_block",
    "CostModel", "ExecutionPlanner", "LevelPlan", "block_degree_stat",
    "load_calibration", "root_block_order",
    "evaluate_level_sampled", "ht_estimate", "ht_interval",
    "normal_quantile", "systematic_sample",
    "MiningConfig", "MiningResult", "PatternStats", "evaluate_pattern",
    "initial_candidates", "mine", "tau_threshold",
]
