"""Distributed FLEXIS mining — shard_map over match roots.

Scaling story (DESIGN.md §4): the data graph is replicated (FSM graphs are
MBs; the *work* is the search), match roots are sharded across every device
in the mesh, and the mIS metric's conflict resolution becomes the collective
signature of the technique:

  per Luby round:   all-reduce(min)  over the (n,) per-vertex priority array
                    all-reduce(sum)  over the packed bitmap word-addends
                    all-reduce(sum)  of the accepted count

Priorities are globally unique (device_index · cap + local row), so winners
are globally vertex-disjoint and the bitwise-OR of retired vertices is an
exact scatter-add — no second pass needed.

Straggler note: blocks are fixed-size and uniform; root-block work variance
(hub vertices) is bounded by the frontier cap, so a step is O(cap · chunks)
on every device regardless of local degree skew — the mitigation is
structural rather than reactive.  The host round-robins super-blocks, which
also gives elastic re-entry: a rescheduled mesh just resumes from the
current super-block with the carried (bitmap, count) state.

Super-blocks are *logical*: a super-block is a fixed run of
``blocks_per_super`` root blocks, dispatched over the mesh ``ndev`` blocks
at a time (tail dispatches padded with empty blocks).  Because the logical
schedule — and therefore the embedding priority order, the per-super-block
early-exit checks, and the (found, overflowed, blocks_run) accounting — is
independent of the mesh shape, the carried ``SuperBlockState`` snapshotted
between super-blocks (`iter_batched_supports`) restores bit-identically on
any device count: greedy mIS selection over a fixed priority order is
invariant to how the order is cut into dispatch batches.  The session
runtime (`repro.runtime`) persists exactly this state for mid-pattern
resume.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import jax_compat

from .graph import DataGraph, DeviceGraph
from .pattern import Pattern
from .plan import PatternPlan, make_plan, stack_plans
from .matcher import MatchConfig, match_block, transient_match_bytes
from . import mis as mis_lib
from . import batched as batched_lib

__all__ = ["mining_mesh", "sharded_mis_step", "distributed_support",
           "sharded_batched_mis_step", "distributed_batched_supports",
           "SuperBlockState", "iter_batched_supports",
           "evaluate_level_distributed"]


def mining_mesh(axis: str = "workers", devices=None) -> Mesh:
    """A 1-D mesh over all available devices (mining shards roots, period)."""
    devices = np.array(jax.devices() if devices is None else devices)
    return jax_compat.make_mesh((devices.size,), (axis,), devices=devices)


def _luby_rounds_global(bitmap, count, emb, n_valid, tau, k: int, n: int,
                        cap: int, axis: str):
    """Globally-synchronized Luby rounds inside shard_map.

    bitmap/count are replicated; emb/n_valid are per-device locals.
    """
    ndev = jax_compat.axis_size(axis)
    didx = jax.lax.axis_index(axis).astype(jnp.int32)
    rowid = jnp.arange(cap, dtype=jnp.int32)
    gprio_base = didx * cap
    INF = jnp.int32(ndev * cap)
    vs = jnp.clip(emb[:, :k], 0, None)
    valid = rowid < n_valid

    def touches(bm):
        return mis_lib.touches_used(bm, vs)

    state0 = (bitmap, count, valid & ~touches(bitmap))

    def cond(state):
        bm, cnt, alive = state
        any_alive = jax.lax.pmax(jnp.any(alive).astype(jnp.int32), axis) > 0
        return any_alive & (cnt < tau)

    def body(state):
        bm, cnt, alive = state
        prio = jnp.where(alive, gprio_base + rowid, INF)
        vmin = jnp.full((n,), INF, dtype=jnp.int32)
        vmin = vmin.at[vs].min(prio[:, None])
        vmin = jax.lax.pmin(vmin, axis)                       # ← collective 1
        win = alive & jnp.all(vmin[vs] == prio[:, None], axis=1)
        # global τ cut in priority order: exclusive prefix of win-counts
        local_wins = win.sum().astype(jnp.int32)
        all_wins = jax.lax.all_gather(local_wins, axis)       # ← collective 2
        prefix = jnp.sum(jnp.where(jnp.arange(ndev) < didx, all_wins, 0))
        win_rank = prefix + jnp.cumsum(win.astype(jnp.int32)) - 1
        win &= win_rank < (tau - cnt)
        words = (vs >> 5).astype(jnp.int32)
        bits = jnp.uint32(1) << (vs & 31).astype(jnp.uint32)
        addend = jnp.zeros_like(bm).at[words].add(
            jnp.where(win[:, None], bits, jnp.uint32(0)))
        addend = jax.lax.psum(addend, axis)                   # ← collective 3
        bm = bm + addend                                      # add ≡ OR here
        cnt = cnt + jax.lax.psum(win.sum().astype(jnp.int32), axis)
        alive = alive & ~win & ~touches(bm)
        return bm, cnt, alive

    bitmap, count, _ = jax.lax.while_loop(cond, body, state0)
    return bitmap, count


@functools.partial(
    jax.jit, static_argnames=("cfg", "k", "n", "axis", "mesh"))
def sharded_mis_step(g: DeviceGraph, plan: PatternPlan, block_starts,
                     bitmap, count, tau, *, cfg: MatchConfig, k: int, n: int,
                     axis: str, mesh: Mesh):
    """One distributed mining step: every device matches its own root block,
    then the mesh resolves mIS conflicts globally.

    block_starts: (ndev,) int32 — one root-block origin per device.
    bitmap/count: replicated metric state. Returns (bitmap, count, found).
    """

    def step(block_start, bm, cnt):
        emb, n_valid, found, _, _ = match_block(g, plan, block_start[0], cfg)
        bm, cnt = _luby_rounds_global(bm, cnt, emb, n_valid, tau, k, n,
                                      cfg.cap, axis)
        return bm, cnt, jax.lax.psum(found, axis)

    return jax_compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )(block_starts, bitmap, count)


@functools.partial(
    jax.jit, static_argnames=("cfg", "k", "n", "axis", "mesh"))
def sharded_batched_mis_step(g: DeviceGraph, plans: PatternPlan, block_starts,
                             bitmaps, counts, taus, *, cfg: MatchConfig,
                             k: int, n: int, axis: str, mesh: Mesh):
    """One distributed step for a whole same-k candidate batch.

    The batched data plane's pattern axis composes with root sharding: roots
    are split across the mesh (``block_starts``: one origin per device) while
    the stacked plans and the (P, …) metric state are replicated and vmapped
    on every device — the pattern axis is pure extra parallelism, the root
    axis is where the collectives run.  Per-pattern results are identical to
    `sharded_mis_step` run pattern-by-pattern (globally-unique priorities are
    per pattern; patterns never interact).

    plans/bitmaps/counts/taus: leading (P,) pattern axis, replicated.
    block_starts: (ndev,) int32 — one root-block origin per device.
    Returns (bitmaps, counts, found, overflowed, peak) with found summed,
    overflow OR-ed and peak frontier occupancy max-ed over the mesh,
    each (P,).
    """

    def step(block_start, bms, cnts):
        def one(plan, bm, cnt, tau):
            emb, n_valid, found, ovf, peak = match_block(
                g, plan, block_start[0], cfg)
            bm, cnt = _luby_rounds_global(bm, cnt, emb, n_valid, tau, k, n,
                                          cfg.cap, axis)
            return bm, cnt, found, ovf, peak

        bms, cnts, found, ovf, peak = jax.vmap(one)(plans, bms, cnts, taus)
        return (bms, cnts, jax.lax.psum(found, axis),
                jax.lax.psum(ovf.astype(jnp.int32), axis) > 0,
                jax.lax.pmax(peak, axis))

    return jax_compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False,
    )(block_starts, bitmaps, counts)


# ---------------------------------------------------------------------------
# resumable super-block schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuperBlockState:
    """Carried state of a batched distributed run between super-blocks.

    This is the unit the session runtime checkpoints (mid-pattern resume):
    ``bitmaps``/``counts`` are the device-side mIS metric state saved as
    *full logical arrays* — the sharded step replicates them (out_specs
    ``P()``), so `np.asarray` yields the logical value and a restore on any
    mesh shape is just handing the host array back to ``shard_map``.  The
    remaining fields are host-side telemetry accumulators plus the
    ``next_block`` cursor (in root-block units).
    """

    next_block: int               # next schedule position (block-order index)
    bitmaps: Any                  # (P, ⌈n/32⌉) uint32 — logical/replicated
    counts: Any                   # (P,) int32
    found: np.ndarray             # (P,) int64, frozen per pattern at τ
    overflowed: np.ndarray        # (P,) bool
    blocks_run: np.ndarray        # (P,) int64, frozen per pattern at τ
    super_blocks_run: int = 0
    dispatches: int = 0           # sharded step invocations (telemetry)
    max_count: Optional[np.ndarray] = None  # (P,) int64 peak occupancy

    def supports(self) -> np.ndarray:
        return np.asarray(self.counts, np.int64)


def _init_super_block_state(P_: int, n: int) -> SuperBlockState:
    return SuperBlockState(
        next_block=0,
        bitmaps=jnp.zeros((P_, mis_lib.bitmap_words(n)), jnp.uint32),
        counts=jnp.zeros((P_,), jnp.int32),
        found=np.zeros(P_, np.int64),
        overflowed=np.zeros(P_, bool),
        blocks_run=np.zeros(P_, np.int64),
        max_count=np.zeros(P_, np.int64),
    )


def iter_batched_supports(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    match_cfg: Optional[MatchConfig] = None,
    complete: bool = False,
    blocks_per_super: Optional[int] = None,
    state: Optional[SuperBlockState] = None,
    block_order: Optional[np.ndarray] = None,
) -> Iterator[SuperBlockState]:
    """Mine a same-k batch one *logical* super-block at a time.

    Yields the carried `SuperBlockState` after every super-block; the caller
    may stop consuming at any yield, snapshot the state, and later rebuild
    the iterator with ``state=`` to continue — on the same or a different
    mesh shape — with bit-identical ``counts``/``bitmaps``/accounting.

    ``blocks_per_super`` fixes the logical super-block width in root blocks
    (default: the current device count, the legacy schedule).  τ early exit
    and the per-pattern (found, overflowed, blocks_run) freeze happen at
    super-block boundaries, so any two runs with the same width agree
    exactly regardless of ``ndev``; runs with different widths agree on
    supports but may differ in the telemetry fields (they see different
    early-exit granularity).

    ``block_order`` is the static root-block schedule (a permutation of
    block ids, `planner.root_block_order`; None = vertex-id order).  The
    super-block cursor — including `SuperBlockState.next_block` — indexes
    into the schedule, which stays mesh-shape-invariant: the permutation
    is a pure function of (graph, root_block, root_order).
    """
    assert len(patterns) == len(taus) and len(patterns) > 0
    k = patterns[0].k
    assert all(p.k == k for p in patterns), "batch must share pattern size"
    mesh = mesh or mining_mesh(axis)
    ndev = int(np.prod(list(mesh.shape.values())))
    cfg = match_cfg or MatchConfig.for_graph(host_g)
    dev_g = DeviceGraph.from_host(host_g)
    plans = stack_plans([make_plan(p, host_g) for p in patterns])
    n = host_g.n
    P_ = len(patterns)
    taus_np = np.asarray(taus, np.int64)
    bps = ndev if blocks_per_super is None else int(blocks_per_super)
    assert bps >= 1

    int32_max = np.iinfo(np.int32).max
    tau_full = np.full(P_, int32_max, np.int64) if complete else taus_np
    tau_dev = jnp.asarray(np.minimum(tau_full, int32_max), jnp.int32)

    if state is None:
        state = _init_super_block_state(P_, n)
    # re-shard on entry: a restored state carries host (logical) arrays
    bitmaps = jnp.asarray(state.bitmaps, jnp.uint32)
    counts = jnp.asarray(state.counts, jnp.int32)
    assert bitmaps.shape == (P_, mis_lib.bitmap_words(n)), bitmaps.shape
    found = state.found.copy()
    ovf = state.overflowed.copy()
    blocks_run = state.blocks_run.copy()
    max_count = (np.zeros(P_, np.int64) if state.max_count is None
                 else state.max_count.copy())
    next_block = int(state.next_block)
    super_blocks = int(state.super_blocks_run)
    dispatches = int(state.dispatches)

    n_blocks = -(-n // cfg.root_block)
    if block_order is None:
        block_order = np.arange(n_blocks, dtype=np.int64)
    assert block_order.shape[0] == n_blocks
    while next_block < n_blocks:
        counts_np = np.asarray(counts, np.int64)
        if not complete and bool((counts_np >= taus_np).all()):
            return
        # per-pattern freeze at super-block granularity: a pattern that
        # already reached τ stops accumulating telemetry (its device state
        # is frozen anyway by the cnt < τ guard in the Luby rounds)
        active = np.ones(P_, bool) if complete else counts_np < taus_np
        stop = min(next_block + bps, n_blocks)
        sb_found = np.zeros(P_, np.int64)
        sb_ovf = np.zeros(P_, bool)
        sb_peak = np.zeros(P_, np.int64)
        for lo in range(next_block, stop, ndev):
            # pad tail dispatches with empty blocks (start ≥ n matches no
            # roots) so a super-block never leaks into the next one
            pos = lo + np.arange(ndev)
            ids = block_order[np.minimum(pos, n_blocks - 1)]
            starts = jnp.asarray(
                np.where(pos < stop, ids * cfg.root_block, n), jnp.int32)
            bitmaps, counts, d_found, d_ovf, d_peak = sharded_batched_mis_step(
                dev_g, plans, starts, bitmaps, counts, tau_dev,
                cfg=cfg, k=k, n=n, axis=axis, mesh=mesh)
            sb_found += np.asarray(d_found, np.int64)
            sb_ovf |= np.asarray(d_ovf, bool)
            sb_peak = np.maximum(sb_peak, np.asarray(d_peak, np.int64))
            dispatches += 1
        found[active] += sb_found[active]
        ovf[active] |= sb_ovf[active]
        blocks_run[active] += stop - next_block
        max_count[active] = np.maximum(max_count[active], sb_peak[active])
        next_block = stop
        super_blocks += 1
        state = SuperBlockState(
            next_block=next_block, bitmaps=bitmaps, counts=counts,
            found=found.copy(), overflowed=ovf.copy(),
            blocks_run=blocks_run.copy(), super_blocks_run=super_blocks,
            dispatches=dispatches, max_count=max_count.copy())
        yield state


def distributed_batched_supports(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    match_cfg: Optional[MatchConfig] = None,
    complete: bool = False,
    blocks_per_super: Optional[int] = None,
    state: Optional[SuperBlockState] = None,
    return_state: bool = False,
):
    """mIS supports of a same-k candidate batch, mined across the whole mesh.

    Returns (supports, found), each (P,) — or (supports, found, state) with
    ``return_state=True``.  Per-pattern semantics match
    `distributed_support`; the host early-exits the super-block loop once
    every pattern has reached its τ (each pattern's ``count < τ`` guard
    freezes its own state as soon as it individually finishes).  Drives
    `iter_batched_supports` to completion; pass ``state=`` to continue a
    snapshotted run.
    """
    last = state if state is not None else _init_super_block_state(
        len(patterns), host_g.n)
    for last in iter_batched_supports(
            host_g, patterns, taus, mesh=mesh, axis=axis, match_cfg=match_cfg,
            complete=complete, blocks_per_super=blocks_per_super, state=state):
        pass
    if return_state:
        return last.supports(), last.found, last
    return last.supports(), last.found


def evaluate_level_distributed(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    cfg: MatchConfig,
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    complete: bool = False,
    deadline: Optional[float] = None,
    max_batch: int = batched_lib.DEFAULT_MAX_BATCH,
    blocks_per_super: Optional[int] = None,
    hooks=None,
    block_order: Optional[np.ndarray] = None,
) -> Tuple[List[Optional["batched_lib.PatternOutcome"]], bool,
           "batched_lib.LevelTelemetry"]:
    """Evaluate a whole candidate level on the mesh (mIS/Luby semantics).

    The distributed counterpart of `batched.evaluate_level_batched`: the
    level is cut into the same deterministic (k, lo) groups, each group is
    mined by `iter_batched_supports` (roots sharded × patterns batched), and
    the same duck-typed ``hooks`` surface drives mid-level resume — here at
    *super-block* granularity, with `SuperBlockState` as the carried unit.
    Supports are bit-identical to the single-device ``mis_luby`` oracle;
    found/overflowed/blocks_run are accounted at super-block granularity
    (see `iter_batched_supports`).

    Timeouts follow the all-or-nothing contract: the deadline is checked
    between super-blocks, and an interrupted group reports ``None`` for
    every pattern still in flight.
    """
    assert len(patterns) == len(taus)
    # fault-injection point for the mesh-failure class: an `error` fault
    # here exercises `mine()`'s distributed→batched fallback exactly the
    # way a real collective/mesh failure would (lazy import — core/ must
    # not require runtime/ at import time)
    try:
        from repro.runtime import faults as _faults
    except ImportError:  # pragma: no cover
        _faults = None
    if _faults is not None:
        _faults.fire("level.distributed")
    mesh = mesh or mining_mesh(axis)
    n = host_g.n
    outcomes: List[Optional[batched_lib.PatternOutcome]] = [None] * len(patterns)
    prefilled = hooks.resume_outcomes() if hooks is not None else None

    timed_out = False
    telemetry = batched_lib.LevelTelemetry()
    if hooks is not None:
        telemetry.dispatches = int(hooks.resume_dispatches())
    for k, lo, idxs in batched_lib.level_groups(patterns, max_batch):
        telemetry.state_bytes = max(
            telemetry.state_bytes,
            len(idxs) * (batched_lib._state_bytes("mis_luby", k, n)
                         + transient_match_bytes(cfg, k)))
        if prefilled is not None and all(i in prefilled for i in idxs):
            for i in idxs:
                outcomes[i] = prefilled[i]
            continue
        group_pats = [patterns[i] for i in idxs]
        group_taus = [taus[i] for i in idxs]
        state = hooks.group_resume(k, lo) if hooks is not None else None
        group_timed_out = False
        it = iter_batched_supports(
            host_g, group_pats, group_taus, mesh=mesh, axis=axis,
            match_cfg=cfg, complete=complete,
            blocks_per_super=blocks_per_super, state=state,
            block_order=block_order)
        last = state if state is not None else _init_super_block_state(
            len(idxs), n)
        while True:
            if deadline is not None and time.monotonic() > deadline:
                group_timed_out = True
                break
            try:
                last = next(it)
            except StopIteration:
                break
            if hooks is not None:
                hooks.on_group_state(k, lo, last)
        telemetry.dispatches += int(last.dispatches)
        if group_timed_out:
            timed_out = True
            break
        sups = last.supports()
        last_max = (last.max_count if last.max_count is not None
                    else np.zeros(len(idxs), np.int64))
        got = [
            batched_lib.PatternOutcome(
                support=int(sups[j]),
                frequent=bool(sups[j] >= group_taus[j]),
                embeddings_found=int(last.found[j]),
                overflowed=bool(last.overflowed[j]),
                blocks_run=int(last.blocks_run[j]),
                max_count=int(last_max[j]),
            )
            for j in range(len(idxs))
        ]
        for i, out in zip(idxs, got):
            outcomes[i] = out
        if hooks is not None:
            hooks.on_group_done(k, lo, idxs, got, int(last.dispatches))
    assert timed_out or all(o is not None for o in outcomes)
    for o in outcomes:
        if o is not None:
            telemetry.max_count = max(telemetry.max_count, o.max_count)
            telemetry.overflowed |= o.overflowed
    return outcomes, timed_out, telemetry


def distributed_support(
    host_g: DataGraph,
    pat: Pattern,
    tau: int,
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    match_cfg: Optional[MatchConfig] = None,
    complete: bool = False,
) -> Tuple[int, int]:
    """mIS support of one pattern, mined across the whole mesh.

    Returns (support, embeddings_found).  Semantics match the single-device
    `evaluate_pattern(metric="mis_luby")`: the complete run yields the
    lexicographically-first maximal independent set in global priority order.
    """
    mesh = mesh or mining_mesh(axis)
    ndev = int(np.prod(list(mesh.shape.values())))
    cfg = match_cfg or MatchConfig.for_graph(host_g)
    dev_g = DeviceGraph.from_host(host_g)
    plan = make_plan(pat, host_g)
    n = host_g.n
    bitmap = mis_lib.bitmap_init(n)
    count = jnp.int32(0)
    tau_dev = jnp.int32(np.iinfo(np.int32).max if complete else tau)
    found_total = 0

    stride = ndev * cfg.root_block
    n_super = -(-n // stride)
    for s in range(n_super):
        starts = jnp.asarray(
            s * stride + np.arange(ndev) * cfg.root_block, jnp.int32)
        bitmap, count, found = sharded_mis_step(
            dev_g, plan, starts, bitmap, count, tau_dev,
            cfg=cfg, k=pat.k, n=n, axis=axis, mesh=mesh)
        found_total += int(found)
        if not complete and int(count) >= tau:
            break
    return int(count), found_total
