"""Distributed FLEXIS mining — shard_map over match roots.

Scaling story (DESIGN.md §4): the data graph is replicated (FSM graphs are
MBs; the *work* is the search), match roots are sharded across every device
in the mesh, and the mIS metric's conflict resolution becomes the collective
signature of the technique:

  per Luby round:   all-reduce(min)  over the (n,) per-vertex priority array
                    all-reduce(sum)  over the packed bitmap word-addends
                    all-reduce(sum)  of the accepted count

Priorities are globally unique (device_index · cap + local row), so winners
are globally vertex-disjoint and the bitwise-OR of retired vertices is an
exact scatter-add — no second pass needed.

Straggler note: blocks are fixed-size and uniform; root-block work variance
(hub vertices) is bounded by the frontier cap, so a step is O(cap · chunks)
on every device regardless of local degree skew — the mitigation is
structural rather than reactive.  The host round-robins super-blocks, which
also gives elastic re-entry: a rescheduled mesh just resumes from the
current super-block with the carried (bitmap, count) state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import jax_compat

from .graph import DataGraph, DeviceGraph
from .pattern import Pattern
from .plan import PatternPlan, make_plan, stack_plans
from .matcher import MatchConfig, match_block
from . import mis as mis_lib

__all__ = ["mining_mesh", "sharded_mis_step", "distributed_support",
           "sharded_batched_mis_step", "distributed_batched_supports"]


def mining_mesh(axis: str = "workers", devices=None) -> Mesh:
    """A 1-D mesh over all available devices (mining shards roots, period)."""
    devices = np.array(jax.devices() if devices is None else devices)
    return jax_compat.make_mesh((devices.size,), (axis,), devices=devices)


def _luby_rounds_global(bitmap, count, emb, n_valid, tau, k: int, n: int,
                        cap: int, axis: str):
    """Globally-synchronized Luby rounds inside shard_map.

    bitmap/count are replicated; emb/n_valid are per-device locals.
    """
    ndev = jax_compat.axis_size(axis)
    didx = jax.lax.axis_index(axis).astype(jnp.int32)
    rowid = jnp.arange(cap, dtype=jnp.int32)
    gprio_base = didx * cap
    INF = jnp.int32(ndev * cap)
    vs = jnp.clip(emb[:, :k], 0, None)
    valid = rowid < n_valid

    def touches(bm):
        return mis_lib.touches_used(bm, vs)

    state0 = (bitmap, count, valid & ~touches(bitmap))

    def cond(state):
        bm, cnt, alive = state
        any_alive = jax.lax.pmax(jnp.any(alive).astype(jnp.int32), axis) > 0
        return any_alive & (cnt < tau)

    def body(state):
        bm, cnt, alive = state
        prio = jnp.where(alive, gprio_base + rowid, INF)
        vmin = jnp.full((n,), INF, dtype=jnp.int32)
        vmin = vmin.at[vs].min(prio[:, None])
        vmin = jax.lax.pmin(vmin, axis)                       # ← collective 1
        win = alive & jnp.all(vmin[vs] == prio[:, None], axis=1)
        # global τ cut in priority order: exclusive prefix of win-counts
        local_wins = win.sum().astype(jnp.int32)
        all_wins = jax.lax.all_gather(local_wins, axis)       # ← collective 2
        prefix = jnp.sum(jnp.where(jnp.arange(ndev) < didx, all_wins, 0))
        win_rank = prefix + jnp.cumsum(win.astype(jnp.int32)) - 1
        win &= win_rank < (tau - cnt)
        words = (vs >> 5).astype(jnp.int32)
        bits = jnp.uint32(1) << (vs & 31).astype(jnp.uint32)
        addend = jnp.zeros_like(bm).at[words].add(
            jnp.where(win[:, None], bits, jnp.uint32(0)))
        addend = jax.lax.psum(addend, axis)                   # ← collective 3
        bm = bm + addend                                      # add ≡ OR here
        cnt = cnt + jax.lax.psum(win.sum().astype(jnp.int32), axis)
        alive = alive & ~win & ~touches(bm)
        return bm, cnt, alive

    bitmap, count, _ = jax.lax.while_loop(cond, body, state0)
    return bitmap, count


@functools.partial(
    jax.jit, static_argnames=("cfg", "k", "n", "axis", "mesh"))
def sharded_mis_step(g: DeviceGraph, plan: PatternPlan, block_starts,
                     bitmap, count, tau, *, cfg: MatchConfig, k: int, n: int,
                     axis: str, mesh: Mesh):
    """One distributed mining step: every device matches its own root block,
    then the mesh resolves mIS conflicts globally.

    block_starts: (ndev,) int32 — one root-block origin per device.
    bitmap/count: replicated metric state. Returns (bitmap, count, found).
    """

    def step(block_start, bm, cnt):
        emb, n_valid, found, _ = match_block(g, plan, block_start[0], cfg)
        bm, cnt = _luby_rounds_global(bm, cnt, emb, n_valid, tau, k, n,
                                      cfg.cap, axis)
        return bm, cnt, jax.lax.psum(found, axis)

    return jax_compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )(block_starts, bitmap, count)


@functools.partial(
    jax.jit, static_argnames=("cfg", "k", "n", "axis", "mesh"))
def sharded_batched_mis_step(g: DeviceGraph, plans: PatternPlan, block_starts,
                             bitmaps, counts, taus, *, cfg: MatchConfig,
                             k: int, n: int, axis: str, mesh: Mesh):
    """One distributed step for a whole same-k candidate batch.

    The batched data plane's pattern axis composes with root sharding: roots
    are split across the mesh (``block_starts``: one origin per device) while
    the stacked plans and the (P, …) metric state are replicated and vmapped
    on every device — the pattern axis is pure extra parallelism, the root
    axis is where the collectives run.  Per-pattern results are identical to
    `sharded_mis_step` run pattern-by-pattern (globally-unique priorities are
    per pattern; patterns never interact).

    plans/bitmaps/counts/taus: leading (P,) pattern axis, replicated.
    block_starts: (ndev,) int32 — one root-block origin per device.
    Returns (bitmaps, counts, found) with found summed over the mesh, (P,).
    """

    def step(block_start, bms, cnts):
        def one(plan, bm, cnt, tau):
            emb, n_valid, found, _ = match_block(g, plan, block_start[0], cfg)
            bm, cnt = _luby_rounds_global(bm, cnt, emb, n_valid, tau, k, n,
                                          cfg.cap, axis)
            return bm, cnt, found

        bms, cnts, found = jax.vmap(one)(plans, bms, cnts, taus)
        return bms, cnts, jax.lax.psum(found, axis)

    return jax_compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axis), P(), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )(block_starts, bitmaps, counts)


def distributed_batched_supports(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    match_cfg: Optional[MatchConfig] = None,
    complete: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """mIS supports of a same-k candidate batch, mined across the whole mesh.

    Returns (supports, found), each (P,).  Per-pattern semantics match
    `distributed_support`; the host early-exits the super-block loop once
    every pattern has reached its τ (each pattern's ``count < τ`` guard
    freezes its own state as soon as it individually finishes).
    """
    assert len(patterns) == len(taus) and len(patterns) > 0
    k = patterns[0].k
    assert all(p.k == k for p in patterns), "batch must share pattern size"
    mesh = mesh or mining_mesh(axis)
    ndev = int(np.prod(list(mesh.shape.values())))
    cfg = match_cfg or MatchConfig.for_graph(host_g)
    dev_g = DeviceGraph.from_host(host_g)
    plans = stack_plans([make_plan(p, host_g) for p in patterns])
    n = host_g.n
    P_ = len(patterns)
    taus_np = np.asarray(taus, np.int64)

    bitmaps = jnp.zeros((P_, mis_lib.bitmap_words(n)), jnp.uint32)
    counts = jnp.zeros((P_,), jnp.int32)
    int32_max = np.iinfo(np.int32).max
    tau_full = np.full(P_, int32_max, np.int64) if complete else taus_np
    tau_dev = jnp.asarray(np.minimum(tau_full, int32_max), jnp.int32)
    found_total = np.zeros(P_, np.int64)

    stride = ndev * cfg.root_block
    n_super = -(-n // stride)
    for s in range(n_super):
        starts = jnp.asarray(
            s * stride + np.arange(ndev) * cfg.root_block, jnp.int32)
        bitmaps, counts, found = sharded_batched_mis_step(
            dev_g, plans, starts, bitmaps, counts, tau_dev,
            cfg=cfg, k=k, n=n, axis=axis, mesh=mesh)
        found_total += np.asarray(found, np.int64)
        if not complete and bool((np.asarray(counts) >= taus_np).all()):
            break
    return np.asarray(counts, np.int64), found_total


def distributed_support(
    host_g: DataGraph,
    pat: Pattern,
    tau: int,
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "workers",
    match_cfg: Optional[MatchConfig] = None,
    complete: bool = False,
) -> Tuple[int, int]:
    """mIS support of one pattern, mined across the whole mesh.

    Returns (support, embeddings_found).  Semantics match the single-device
    `evaluate_pattern(metric="mis_luby")`: the complete run yields the
    lexicographically-first maximal independent set in global priority order.
    """
    mesh = mesh or mining_mesh(axis)
    ndev = int(np.prod(list(mesh.shape.values())))
    cfg = match_cfg or MatchConfig.for_graph(host_g)
    dev_g = DeviceGraph.from_host(host_g)
    plan = make_plan(pat, host_g)
    n = host_g.n
    bitmap = mis_lib.bitmap_init(n)
    count = jnp.int32(0)
    tau_dev = jnp.int32(np.iinfo(np.int32).max if complete else tau)
    found_total = 0

    stride = ndev * cfg.root_block
    n_super = -(-n // stride)
    for s in range(n_super):
        starts = jnp.asarray(
            s * stride + np.arange(ndev) * cfg.root_block, jnp.int32)
        bitmap, count, found = sharded_mis_step(
            dev_g, plan, starts, bitmap, count, tau_dev,
            cfg=cfg, k=pat.k, n=n, axis=axis, mesh=mesh)
        found_total += int(found)
        if not complete and int(count) >= tau:
            break
    return int(count), found_total
