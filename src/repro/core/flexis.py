"""FLEXIS — Algorithm 1: the level-wise mining loop.

Host control plane: candidate generation (Alg 2–4), τ computation (Eq. 1),
early termination, timeout.  Device data plane: by default the *batched*
executor (`core/batched.py`) — every same-k candidate group of a level runs
as one vmapped jit program with per-pattern τ masking — with the paper's
one-pattern-at-a-time loop retained as the ``execution="sequential"``
oracle (`evaluate_pattern`, one jit per pattern size).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from .graph import DataGraph, DeviceGraph
from .health import RunHealth
from .pattern import Pattern
from .canonical import canonical_key, dedupe_patterns
from .generation import edge_extension_candidates, generate_new_patterns
from .matcher import MatchConfig, match_block, transient_match_bytes
from .plan import make_plan
from .planner import CostModel, ExecutionPlanner, LevelPlan
from . import planner as planner_lib
from . import batched as batched_lib
from . import sampled as sampled_lib
from . import mis as mis_lib
from . import metrics as metrics_lib

__all__ = ["MiningConfig", "MiningLoopState", "PatternStats", "MiningResult",
           "tau_threshold", "mine", "evaluate_pattern", "initial_candidates"]

_METRICS = ("mis", "mis_luby", "mni", "frac", "mis_exact")
_GENERATION = ("merge", "edge_ext")
_EXECUTION = ("auto", "batched", "sequential", "distributed", "sampled")
_ROOT_ORDERS = ("degree", "vertex")


@dataclasses.dataclass(frozen=True)
class MiningConfig:
    sigma: int
    lam: float = 0.4
    metric: str = "mis"            # one of _METRICS
    generation: str = "merge"      # one of _GENERATION
    max_pattern_size: int = 5
    complete: bool = False         # disable τ early exit (exact metric values)
    time_limit_s: Optional[float] = None
    match: MatchConfig = dataclasses.field(default_factory=MatchConfig)
    # data plane: "auto" (default) consults the execution planner
    # (`core/planner.py`) per level — cost-model plane choice, bucket
    # sizing, and occupancy-derived matcher geometry, with every decision
    # recorded in per_level["plan"]; "batched" stacks each same-k candidate
    # group of a level into one vmapped device program; "sequential" is the
    # paper's one-pattern-at-a-time loop, kept as the equivalence oracle;
    # "distributed" shards match roots over every local device (shard_map,
    # `core/distributed.py`) — Luby semantics, so metric must be mis_luby.
    # (mis_exact always takes the sequential path — its MIS solve is
    # host-side, though its embedding collection is block-batched.)
    # "sampled" (`core/sampled.py`) runs a weighted root-block sample per
    # level, estimates support Horvitz–Thompson-style, and escalates every
    # pattern whose confidence interval reaches τ to the exact batched
    # plane — the frequent set and its supports stay bit-identical to
    # forced batched while clearly-infrequent patterns are priced at the
    # sample fraction.
    execution: str = "auto"
    # ceiling on the pattern axis of one batched program (transient device
    # memory is O(batch · cap · chunk); bigger levels are sliced)
    batch_patterns: int = 64
    # distributed plane only: logical super-block width in root blocks —
    # fixes the early-exit/accounting schedule independent of the mesh
    # shape, which is what lets a checkpointed run resume on a different
    # device count bit-identically.  None = current device count (legacy).
    # (Under execution="auto" the planner only *considers* the distributed
    # plane when this is set — an unpinned schedule is mesh-dependent.)
    blocks_per_super: Optional[int] = None
    # root-block schedule: "degree" dispatches blocks in descending
    # max-out-degree order so high-yield roots run first and τ early exit
    # fires sooner; "vertex" is the legacy vertex-id order.  The schedule
    # is shared by every plane and is part of the session fingerprint —
    # completed metric values are deterministic *within* a schedule
    # (mIS priority = embedding-row order along it).
    root_order: str = "degree"
    # sampled plane knobs (also consulted when execution="auto" prices a
    # sampled pass).  All of them join the session config fingerprint, so
    # a --resume with a different sample schedule raises SessionMismatch
    # instead of silently mixing two different draws.
    sample_fraction: float = 0.25   # target fraction of root blocks drawn
    confidence: float = 0.95        # nominal CI level for the estimator
    sample_seed: int = 0            # RNG key root for the per-level draws
    escalate: bool = True           # False = pure estimates (no exactness)
    sample_rounds: int = 3          # max adaptive draw rounds per level

    def __post_init__(self):
        if self.metric not in _METRICS:
            raise ValueError(f"metric must be one of {_METRICS}")
        if self.generation not in _GENERATION:
            raise ValueError(f"generation must be one of {_GENERATION}")
        if self.execution not in _EXECUTION:
            raise ValueError(f"execution must be one of {_EXECUTION}")
        if self.execution == "distributed" and self.metric != "mis_luby":
            raise ValueError(
                'execution="distributed" resolves mIS with globally-'
                'synchronized Luby rounds; set metric="mis_luby"')
        if self.batch_patterns < 1:
            raise ValueError("batch_patterns must be >= 1")
        if self.blocks_per_super is not None and self.blocks_per_super < 1:
            raise ValueError("blocks_per_super must be >= 1 (or None)")
        if self.root_order not in _ROOT_ORDERS:
            raise ValueError(f"root_order must be one of {_ROOT_ORDERS}")
        if not (0.0 <= self.lam <= 1.0):
            raise ValueError("lambda (slider) must be in [0, 1]")
        if self.execution == "sampled" and self.metric == "mis_exact":
            raise ValueError(
                'execution="sampled" estimates from block telemetry; '
                "mis_exact's host-side MIS solve has no batched escalation "
                "target — use a batchable metric")
        if not (0.0 < self.sample_fraction <= 1.0):
            raise ValueError("sample_fraction must be in (0, 1]")
        if not (0.0 < self.confidence < 1.0):
            raise ValueError("confidence must be in (0, 1)")
        if self.sample_rounds < 1:
            raise ValueError("sample_rounds must be >= 1")


@dataclasses.dataclass
class PatternStats:
    pattern: Pattern
    support: int
    tau: int
    frequent: bool
    embeddings_found: int
    overflowed: bool
    blocks_run: int
    # peak frontier occupancy over the blocks this pattern ran (≤ cap) —
    # surfaced per level as per_level["max_count"], the planner's input
    max_count: int = 0
    # device program invocations (== blocks_run except where a dispatch
    # covers several blocks, e.g. mis_exact's batched embedding collection)
    dispatches: int = 0
    # sampled plane only: True when `support` is a Horvitz–Thompson
    # estimate clamped below τ (never True for a frequent pattern —
    # escalation recomputes those exactly)
    estimated: bool = False


@dataclasses.dataclass
class MiningResult:
    frequent: List[Tuple[Pattern, int]]
    searched: int                       # candidate patterns evaluated (Table 2)
    # per level: candidates/searched/pruned/frequent counts plus telemetry —
    # "dispatches" (device program invocations; deterministic, carried
    # across a session resume), "max_count"/"overflowed" (peak frontier
    # occupancy across the level's patterns and whether any hit the cap —
    # the planner's geometry inputs), "plan" (the planner's recorded
    # decision dict, present under execution="auto") and "wall_s" (wall
    # clock spent on the level *in this process*; excluded from resume
    # bit-identity comparisons)
    per_level: Dict[int, Dict[str, Any]]
    stats: List[PatternStats]
    elapsed_s: float
    timed_out: bool
    peak_device_bytes: int
    # every recovery/fallback/retry the run performed (overflow
    # escalations, plane fallbacks, checkpoint repairs when run under a
    # session) — results are bit-identical with or without them; see
    # `core/health.py`.  Excluded from resume bit-identity comparisons.
    health: RunHealth = dataclasses.field(default_factory=RunHealth)


@dataclasses.dataclass
class MiningLoopState:
    """The host loop's full carried state at a level boundary.

    This is what the session runtime (`repro.runtime`) snapshots: handing a
    `MiningLoopState` back to `mine()` via hooks resumes the loop exactly
    where it stopped — ``cp`` is the candidate list of the *next* level
    (empty once mining finished, which makes a resumed finished run a
    no-op that just re-materializes the result).
    """

    level: int                          # levels already completed
    cp: List[Pattern]                   # candidates of the next level
    frequent: List[Tuple[Pattern, int]]
    stats: List[PatternStats]
    per_level: Dict[int, Dict[str, Any]]
    searched: int
    peak_bytes: int
    elapsed_s: float                    # wall time consumed up to the snapshot
    timed_out: bool = False


def tau_threshold(sigma: int, lam: float, n_vertices: int) -> int:
    """Paper Eq. (1): τ = ⌊σ(1 − 1/n)λ + σ/n⌋, clamped to ≥ 1."""
    n = max(n_vertices, 1)
    return max(1, math.floor(sigma * (1.0 - 1.0 / n) * lam + sigma / n))


def initial_candidates(g: DataGraph) -> List[Pattern]:
    """CP ← EDGES(G): the size-2 patterns actually present in the graph."""
    src = np.repeat(np.arange(g.n), np.diff(g.out_indptr))
    dst = g.out_indices
    la, lb = g.labels[src], g.labels[dst]
    pairs = np.unique(np.stack([la, lb], axis=1), axis=0) if src.size else np.zeros((0, 2), int)
    # reciprocated label pairs (u⇄v exists with these labels)
    rev_keys = set()
    if src.size:
        keys = set(zip(src.tolist(), dst.tolist()))
        mutual = np.array([(s, d) in keys and (d, s) in keys for s, d in zip(src, dst)])
        mpairs = np.unique(np.stack([la[mutual], lb[mutual]], axis=1), axis=0) if mutual.any() else np.zeros((0, 2), int)
        rev_keys = {tuple(p) for p in mpairs.tolist()}
    out: List[Pattern] = []
    for a, b in pairs.tolist():
        adj = np.zeros((2, 2), dtype=bool)
        adj[0, 1] = True
        out.append(Pattern(adj, np.array([a, b], np.int32)))
    for a, b in sorted(rev_keys):
        adj = np.zeros((2, 2), dtype=bool)
        adj[0, 1] = adj[1, 0] = True
        out.append(Pattern(adj, np.array([a, b], np.int32)))
    return dedupe_patterns(out)


def evaluate_pattern(
    host_g: DataGraph,
    dev_g: DeviceGraph,
    pat: Pattern,
    tau: int,
    cfg: MiningConfig,
    *,
    match_cfg: Optional[MatchConfig] = None,
    block_order: Optional[np.ndarray] = None,
) -> PatternStats:
    """Metric step for one candidate: stream root blocks until τ or done.

    ``match_cfg`` overrides ``cfg.match`` (the planner's per-level
    geometry); ``block_order`` is the static root-block schedule (a
    permutation of block ids; None = vertex-id order).  ``mis_exact``
    collects embeddings with the block-batched device collector
    (`batched.collect_pattern_embeddings`) — same per-block results, far
    fewer dispatches — and solves MIS exactly on host.
    """
    mcfg = cfg.match if match_cfg is None else match_cfg
    plan = make_plan(pat, host_g)
    k = pat.k
    n = host_g.n
    metric = cfg.metric
    early_exit_tau = jnp.int32(np.iinfo(np.int32).max if cfg.complete else tau)
    n_blocks = -(-n // mcfg.root_block)
    if block_order is None:
        block_order = np.arange(n_blocks, dtype=np.int64)

    if metric == "mis_exact":
        embs, found_total, overflowed, blocks, peak, dispatches = \
            batched_lib.collect_pattern_embeddings(
                dev_g, plan, mcfg, n, block_order=block_order)
        support = metrics_lib.exact_mis(embs)
        return PatternStats(
            pattern=pat, support=support, tau=tau,
            frequent=support >= tau, embeddings_found=found_total,
            overflowed=overflowed, blocks_run=blocks,
            max_count=peak, dispatches=dispatches)

    if metric in ("mis", "mis_luby"):
        state = (mis_lib.bitmap_init(n), jnp.int32(0))
    elif metric == "mni":
        state = metrics_lib.mni_init(k, n)
    else:  # frac
        state = metrics_lib.frac_init(k, n)

    found_total = 0
    overflowed = False
    blocks = 0
    max_count = 0
    for b in range(n_blocks):
        emb, count, found, ovf, peak = match_block(
            dev_g, plan, jnp.int32(int(block_order[b]) * mcfg.root_block),
            mcfg)
        blocks += 1
        found_total += int(found)
        overflowed |= bool(ovf)
        max_count = max(max_count, int(peak))
        if metric == "mis":
            state = mis_lib.mis_greedy_update(state[0], state[1], emb, count, early_exit_tau, k)
            if not cfg.complete and int(state[1]) >= tau:
                break
        elif metric == "mis_luby":
            state = mis_lib.mis_luby_update(state[0], state[1], emb, count, early_exit_tau, k, n)
            if not cfg.complete and int(state[1]) >= tau:
                break
        elif metric == "mni":
            state = metrics_lib.mni_update(state, emb, count, k)
            if not cfg.complete and int(metrics_lib.mni_value(state)) >= tau:
                break
        else:  # frac
            state = metrics_lib.frac_update(state, emb, count, k)

    if metric in ("mis", "mis_luby"):
        support = int(state[1])
    elif metric == "mni":
        support = int(metrics_lib.mni_value(state))
    else:
        support = int(math.floor(float(metrics_lib.frac_value(state))))

    return PatternStats(
        pattern=pat,
        support=support,
        tau=tau,
        frequent=support >= tau,
        embeddings_found=found_total,
        overflowed=overflowed,
        blocks_run=blocks,
        max_count=max_count,
        dispatches=blocks,
    )


def _device_bytes(mcfg: MatchConfig, metric: str, k: int, n: int) -> int:
    graphless = transient_match_bytes(mcfg, k)
    if metric in ("mis", "mis_luby"):
        graphless += ((n + 31) // 32) * 4 + (n * 4 if metric == "mis_luby" else 0)
    elif metric == "mni":
        graphless += k * n
    elif metric == "frac":
        graphless += k * n * 4
    elif metric == "mis_exact":
        # block-batched embedding collection stacks whole blocks' transient
        # state on the vmapped leading axis
        graphless *= batched_lib.MIS_EXACT_BLOCKS_PER_DISPATCH
    return graphless


def mine(g: DataGraph, cfg: MiningConfig, *, hooks=None,
         health: Optional[RunHealth] = None) -> MiningResult:
    """Algorithm 1.  Returns all frequent patterns + the paper's telemetry.

    ``health`` is the run's `RunHealth` report (a fresh one when omitted;
    sessions pass theirs in so checkpoint-layer recoveries and execution-
    layer degradations land in the same log).  Two degradations happen
    here: patterns that overflow an auto-derived cap are re-run at the
    base cap (``overflow_escalation`` — restores forced-plane equality),
    and a failing distributed level is re-run on the batched plane
    (``plane_fallback`` — supports are plane-invariant).

    ``hooks`` is the session runtime's resume surface (duck-typed; see
    `repro.runtime.session.MiningSession`):

      * ``hooks.loop_resume()`` → Optional[`MiningLoopState`] — restart the
        loop from a level-boundary snapshot instead of from scratch;
      * ``hooks.level_hooks(level)`` → Optional[object] — per-level hooks
        handed to the level executor (mid-level / mid-pattern resume:
        `batched.evaluate_level_batched` / `distributed
        .evaluate_level_distributed` document the surface);
      * ``hooks.on_level_end(MiningLoopState)`` — called at every level
        boundary (and once more, with ``cp=[]``, when mining finishes) with
        the full carried loop state.

    A run resumed from any snapshot produces the same `MiningResult` as the
    uninterrupted run, except wall-clock fields (``elapsed_s``, per-level
    ``wall_s``).
    """
    t0 = time.monotonic()
    if health is None:
        health = RunHealth()
    dev_g = DeviceGraph.from_host(g)
    graph_bytes = g.nbytes()

    resume = hooks.loop_resume() if hooks is not None else None
    if resume is None:
        frequent: List[Tuple[Pattern, int]] = []
        all_stats: List[PatternStats] = []
        per_level: Dict[int, Dict[str, Any]] = {}
        searched = 0
        peak_bytes = graph_bytes
        timed_out = False
        cp = initial_candidates(g)
        level = 0
        elapsed0 = 0.0
    else:
        frequent = list(resume.frequent)
        all_stats = list(resume.stats)
        per_level = dict(resume.per_level)
        searched = resume.searched
        peak_bytes = max(graph_bytes, resume.peak_bytes)
        timed_out = resume.timed_out
        cp = list(resume.cp)
        level = resume.level
        elapsed0 = resume.elapsed_s

    label_universe = sorted(set(g.labels.tolist()))
    searched_keys = {canonical_key(st.pattern) for st in all_stats}
    mis_mode = cfg.metric in ("mis", "mis_luby", "mis_exact")

    # the execution planner: forced modes pass through it unchanged, "auto"
    # applies the calibrated cost model per level; every plane walks the
    # planner's static root-block schedule (cfg.root_order)
    import jax

    cost = planner_lib.load_calibration()
    n_devices = jax.local_device_count()
    if hooks is not None and hasattr(hooks, "pin_calibration"):
        # sessions pin the planner inputs in the snapshot so a resume on a
        # machine with a different calibration file — or a different
        # device count — replans identically (CostModel.from_dict ignores
        # the extra n_devices key)
        pinned = hooks.pin_calibration(
            {**cost.to_dict(), "n_devices": n_devices})
        cost = CostModel.from_dict(pinned)
        n_devices = int(pinned.get("n_devices", n_devices))
    planner = ExecutionPlanner(g, cfg, cost_model=cost,
                               n_devices=n_devices)
    block_order = planner.block_order
    deadline = (None if cfg.time_limit_s is None
                else t0 + max(cfg.time_limit_s - elapsed0, 0.0))

    def loop_state(next_cp: List[Pattern]) -> MiningLoopState:
        return MiningLoopState(
            level=level, cp=list(next_cp), frequent=list(frequent),
            stats=list(all_stats), per_level=dict(per_level),
            searched=searched, peak_bytes=peak_bytes,
            elapsed_s=elapsed0 + (time.monotonic() - t0),
            timed_out=timed_out)

    while cp:
        level += 1
        level_t0 = time.monotonic()
        level_hooks = hooks.level_hooks(level) if hooks is not None else None
        level_frequent: List[Pattern] = []
        lvl_searched = 0
        lvl_pruned = 0
        lvl_dispatches = 0
        lvl_max_count = 0
        lvl_overflowed = False
        eval_pats: List[Pattern] = []
        eval_taus: List[int] = []
        for pat in cp:
            tau = (
                tau_threshold(cfg.sigma, cfg.lam, pat.k) if mis_mode else cfg.sigma
            )
            # paper §3.1.2 vertex bound: a frequent k-pattern needs k·τ
            # distinct data vertices under the independence property
            if mis_mode and pat.k * tau > g.n:
                lvl_pruned += 1
                continue
            eval_pats.append(pat)
            eval_taus.append(tau)

        # plan the level: a mid-level resume replays the recorded decision
        # (calibration drift between processes must not move the plan);
        # otherwise the planner decides from the previous level's telemetry
        plan: Optional[LevelPlan] = None
        if level_hooks is not None:
            resume_plan = getattr(level_hooks, "resume_plan", None)
            d = resume_plan() if resume_plan is not None else None
            if d is not None:
                plan = LevelPlan.from_dict(d, cfg.match)
        if plan is None:
            plan = planner.plan_level(level, eval_pats, eval_taus,
                                      prev=per_level.get(level - 1))
        if level_hooks is not None and cfg.execution in ("auto", "sampled"):
            # sampled plans are recorded too: the level's block draw lives
            # in plan.sample and a resume must replay it, not re-draw it
            record_plan = getattr(level_hooks, "record_plan", None)
            if record_plan is not None:
                record_plan(plan.to_dict())
        plane = plan.plane if cfg.metric != "mis_exact" else "sequential"

        tel = None
        if plane in ("batched", "distributed", "sampled") and eval_pats:
            if plane == "sampled":
                outcomes, lvl_timed_out, tel = sampled_lib.evaluate_level_sampled(
                    g, dev_g, eval_pats, eval_taus, cfg.metric, plan.match,
                    sample=plan.sample, confidence=cfg.confidence,
                    escalate=cfg.escalate, complete=cfg.complete,
                    deadline=deadline, max_batch=plan.max_batch,
                    hooks=level_hooks, block_order=block_order,
                    sample_rounds=cfg.sample_rounds)
            elif plane == "distributed":
                from . import distributed as distributed_lib

                try:
                    outcomes, lvl_timed_out, tel = distributed_lib.evaluate_level_distributed(
                        g, eval_pats, eval_taus, plan.match,
                        complete=cfg.complete, deadline=deadline,
                        max_batch=plan.max_batch,
                        blocks_per_super=cfg.blocks_per_super,
                        hooks=level_hooks, block_order=block_order)
                except Exception as e:
                    # graceful degradation: a failed mesh/collective — or a
                    # mesh that can no longer satisfy the recorded plan —
                    # must not fail the query.  Re-run the level on the
                    # batched plane: supports are bit-identical by the
                    # plane-equivalence contract, and completed groups the
                    # failed attempt recorded are replayed (only the
                    # in-flight super-block cursor is dropped — it is the
                    # wrong plane's resume unit).  `InjectedCrash` and
                    # `PreemptedError` are BaseExceptions and fly past this
                    # on purpose: a kill is not a mesh failure.
                    health.record(
                        "plane_fallback",
                        f"distributed level failed "
                        f"({type(e).__name__}: {e}); degrading to batched",
                        level=level)
                    if level_hooks is not None:
                        drop = getattr(level_hooks, "drop_inflight", None)
                        if drop is not None:
                            drop()
                    plan = dataclasses.replace(plan, plane="batched")
                    if level_hooks is not None:
                        record_plan = getattr(level_hooks, "record_plan",
                                              None)
                        if record_plan is not None:
                            # a mid-level snapshot after this point must
                            # resume on the batched plane, whatever the
                            # original plan said
                            record_plan(plan.to_dict())
                    plane = "batched"
                    outcomes, lvl_timed_out, tel = batched_lib.evaluate_level_batched(
                        g, dev_g, eval_pats, eval_taus, cfg.metric,
                        plan.match, complete=cfg.complete, deadline=deadline,
                        max_batch=plan.max_batch, hooks=level_hooks,
                        block_order=block_order)
            else:
                # within-level replanning is an auto-plane behaviour: the
                # forced batched plane is the bit-identity oracle and must
                # keep the config geometry verbatim
                outcomes, lvl_timed_out, tel = batched_lib.evaluate_level_batched(
                    g, dev_g, eval_pats, eval_taus, cfg.metric, plan.match,
                    complete=cfg.complete, deadline=deadline,
                    max_batch=plan.max_batch, hooks=level_hooks,
                    block_order=block_order,
                    replan=cfg.execution == "auto")
            timed_out |= lvl_timed_out
            lvl_dispatches += tel.dispatches
            lvl_max_count = max(lvl_max_count, tel.max_count)
            lvl_overflowed |= tel.overflowed
            peak_bytes = max(peak_bytes, graph_bytes + tel.state_bytes)
            # graceful degradation, exactness half: the planner's
            # right-sized cap guarantees headroom only over the *previous*
            # level's peak, so a level can still overflow it.  Truncation
            # is the only cap-dependent behaviour, so re-running just the
            # overflowed patterns at the config's base geometry restores
            # forced-plane equality (a non-overflowed pattern's history is
            # cap-invariant, hence identical to the base-cap run already).
            # Pure function of the recorded outcomes → a resumed run
            # escalates identically.
            esc = [i for i, o in enumerate(outcomes)
                   if o is not None and o.overflowed]
            # a within-level replan can shrink the cap below the plan's,
            # so replans make the level escalation-eligible even when the
            # plan kept the base geometry
            replanned = tel is not None and getattr(tel, "replans", 0) > 0
            if esc and not timed_out \
                    and (plan.match.cap < cfg.match.cap or replanned):
                re_out, re_to, re_tel = batched_lib.evaluate_level_batched(
                    g, dev_g, [eval_pats[i] for i in esc],
                    [eval_taus[i] for i in esc], cfg.metric, cfg.match,
                    complete=cfg.complete, deadline=deadline,
                    max_batch=plan.max_batch, block_order=block_order)
                timed_out |= re_to
                lvl_dispatches += re_tel.dispatches
                peak_bytes = max(peak_bytes, graph_bytes + re_tel.state_bytes)
                outcomes = list(outcomes)
                done = 0
                for i, o in zip(esc, re_out):
                    if o is not None:
                        outcomes[i] = o
                        done += 1
                # occupancy telemetry must describe the *final* outcomes
                # (forced-plane equality covers max_count/overflowed too,
                # and the next level's plan is derived from these)
                lvl_max_count = max((o.max_count for o in outcomes
                                     if o is not None), default=0)
                lvl_overflowed = any(o.overflowed for o in outcomes
                                     if o is not None)
                health.record(
                    "overflow_escalation",
                    f"{done}/{len(esc)} patterns overflowed derived cap "
                    f"{plan.match.cap}; re-run at base cap {cfg.match.cap}",
                    level=level)
            for pat, tau, out in zip(eval_pats, eval_taus, outcomes):
                if out is None:  # level timed out before this group ran
                    continue
                st = PatternStats(
                    pattern=pat,
                    support=out.support,
                    tau=tau,
                    frequent=out.frequent,
                    embeddings_found=out.embeddings_found,
                    overflowed=out.overflowed,
                    blocks_run=out.blocks_run,
                    max_count=out.max_count,
                    estimated=getattr(out, "estimated", False),
                )
                searched += 1
                lvl_searched += 1
                all_stats.append(st)
                if st.frequent:
                    frequent.append((pat, st.support))
                    level_frequent.append(pat)
        else:
            seq_stats: List[PatternStats] = []
            for pat, tau in zip(eval_pats, eval_taus):
                if deadline is not None and time.monotonic() > deadline:
                    timed_out = True
                    break
                st = evaluate_pattern(g, dev_g, pat, tau, cfg,
                                      match_cfg=plan.match,
                                      block_order=block_order)
                lvl_dispatches += st.dispatches
                seq_stats.append(st)
                peak_bytes = max(
                    peak_bytes,
                    graph_bytes + _device_bytes(plan.match, cfg.metric,
                                                pat.k, g.n))
            # same overflow-escalation pass as the plane branch (the
            # sequential plane carries an auto-derived cap too — mis_exact
            # under execution="auto" in particular always lands here)
            if plan.match.cap < cfg.match.cap and not timed_out:
                n_esc = 0
                for j, st in enumerate(seq_stats):
                    if not st.overflowed:
                        continue
                    if deadline is not None and time.monotonic() > deadline:
                        timed_out = True
                        break
                    st = evaluate_pattern(g, dev_g, st.pattern, st.tau, cfg,
                                          match_cfg=cfg.match,
                                          block_order=block_order)
                    lvl_dispatches += st.dispatches
                    seq_stats[j] = st
                    n_esc += 1
                    peak_bytes = max(
                        peak_bytes,
                        graph_bytes + _device_bytes(cfg.match, cfg.metric,
                                                    st.pattern.k, g.n))
                if n_esc:
                    health.record(
                        "overflow_escalation",
                        f"{n_esc} patterns overflowed derived cap "
                        f"{plan.match.cap}; re-run at base cap "
                        f"{cfg.match.cap}", level=level)
            for st in seq_stats:
                searched += 1
                lvl_searched += 1
                lvl_max_count = max(lvl_max_count, st.max_count)
                lvl_overflowed |= st.overflowed
                all_stats.append(st)
                if st.frequent:
                    frequent.append((st.pattern, st.support))
                    level_frequent.append(st.pattern)
        per_level[level] = {
            "candidates": len(cp),
            "searched": lvl_searched,
            "pruned": lvl_pruned,
            "frequent": len(level_frequent),
            "dispatches": lvl_dispatches,
            "max_count": int(lvl_max_count),
            "overflowed": bool(lvl_overflowed),
            "wall_s": time.monotonic() - level_t0,
        }
        if cfg.execution in ("auto", "sampled"):
            per_level[level]["plan"] = plan.to_dict()
            # planner-input telemetry: cross-plane per_level comparisons
            # (the batched ≡ sequential ≡ auto tests) drop these keys
            if tel is not None and tel.sampled is not None:
                per_level[level]["sampled"] = tel.sampled
            if tel is not None and tel.block_peaks is not None:
                # block-id indexed peak occupancy — next level's draw weights
                per_level[level]["block_peaks"] = [
                    int(x) for x in tel.block_peaks]
        if cfg.execution == "auto" and tel is not None:
            per_level[level]["replans"] = int(getattr(tel, "replans", 0))
        if timed_out or not level_frequent:
            cp = []
        elif (cfg.generation == "merge"
              and level_frequent[0].k + 1 > cfg.max_pattern_size):
            # merge keeps strict level-wise (k−1 → k) discipline
            cp = []
        else:
            if cfg.generation == "merge":
                cp = generate_new_patterns(level_frequent)
            else:
                # edge extension mixes vertex counts (that is the paper's
                # point: same-vertex-count patterns land at different BFS
                # levels)
                cp = edge_extension_candidates(
                    level_frequent, label_universe, max_k=cfg.max_pattern_size
                )
            searched_keys |= {canonical_key(st.pattern) for st in all_stats}
            cp = [
                p for p in cp
                if p.k <= cfg.max_pattern_size and canonical_key(p) not in searched_keys
            ]
        if hooks is not None:
            hooks.on_level_end(loop_state(cp))

    return MiningResult(
        frequent=frequent,
        searched=searched,
        per_level=per_level,
        stats=all_stats,
        elapsed_s=elapsed0 + (time.monotonic() - t0),
        timed_out=timed_out,
        peak_device_bytes=peak_bytes,
        health=health,
    )
