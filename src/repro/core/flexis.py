"""FLEXIS — Algorithm 1: the level-wise mining loop.

Host control plane: candidate generation (Alg 2–4), τ computation (Eq. 1),
early termination, timeout.  Device data plane: by default the *batched*
executor (`core/batched.py`) — every same-k candidate group of a level runs
as one vmapped jit program with per-pattern τ masking — with the paper's
one-pattern-at-a-time loop retained as the ``execution="sequential"``
oracle (`evaluate_pattern`, one jit per pattern size).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from .graph import DataGraph, DeviceGraph
from .pattern import Pattern
from .canonical import canonical_key, dedupe_patterns
from .generation import edge_extension_candidates, generate_new_patterns
from .matcher import MatchConfig, match_block, transient_match_bytes
from .plan import make_plan
from . import batched as batched_lib
from . import mis as mis_lib
from . import metrics as metrics_lib

__all__ = ["MiningConfig", "MiningLoopState", "PatternStats", "MiningResult",
           "tau_threshold", "mine", "evaluate_pattern", "initial_candidates"]

_METRICS = ("mis", "mis_luby", "mni", "frac", "mis_exact")
_GENERATION = ("merge", "edge_ext")
_EXECUTION = ("batched", "sequential", "distributed")


@dataclasses.dataclass(frozen=True)
class MiningConfig:
    sigma: int
    lam: float = 0.4
    metric: str = "mis"            # one of _METRICS
    generation: str = "merge"      # one of _GENERATION
    max_pattern_size: int = 5
    complete: bool = False         # disable τ early exit (exact metric values)
    time_limit_s: Optional[float] = None
    match: MatchConfig = dataclasses.field(default_factory=MatchConfig)
    # data plane: "batched" stacks each same-k candidate group of a level
    # into one vmapped device program; "sequential" is the paper's
    # one-pattern-at-a-time loop, kept as the equivalence oracle;
    # "distributed" shards match roots over every local device (shard_map,
    # `core/distributed.py`) — Luby semantics, so metric must be mis_luby.
    # (mis_exact always takes the sequential path — its MIS solve is host-side.)
    execution: str = "batched"
    # ceiling on the pattern axis of one batched program (transient device
    # memory is O(batch · cap · chunk); bigger levels are sliced)
    batch_patterns: int = 64
    # distributed plane only: logical super-block width in root blocks —
    # fixes the early-exit/accounting schedule independent of the mesh
    # shape, which is what lets a checkpointed run resume on a different
    # device count bit-identically.  None = current device count (legacy).
    blocks_per_super: Optional[int] = None

    def __post_init__(self):
        if self.metric not in _METRICS:
            raise ValueError(f"metric must be one of {_METRICS}")
        if self.generation not in _GENERATION:
            raise ValueError(f"generation must be one of {_GENERATION}")
        if self.execution not in _EXECUTION:
            raise ValueError(f"execution must be one of {_EXECUTION}")
        if self.execution == "distributed" and self.metric != "mis_luby":
            raise ValueError(
                'execution="distributed" resolves mIS with globally-'
                'synchronized Luby rounds; set metric="mis_luby"')
        if self.batch_patterns < 1:
            raise ValueError("batch_patterns must be >= 1")
        if self.blocks_per_super is not None and self.blocks_per_super < 1:
            raise ValueError("blocks_per_super must be >= 1 (or None)")
        if not (0.0 <= self.lam <= 1.0):
            raise ValueError("lambda (slider) must be in [0, 1]")


@dataclasses.dataclass
class PatternStats:
    pattern: Pattern
    support: int
    tau: int
    frequent: bool
    embeddings_found: int
    overflowed: bool
    blocks_run: int


@dataclasses.dataclass
class MiningResult:
    frequent: List[Tuple[Pattern, int]]
    searched: int                       # candidate patterns evaluated (Table 2)
    # per level: candidates/searched/pruned/frequent counts plus telemetry —
    # "dispatches" (device program invocations; deterministic, carried
    # across a session resume) and "wall_s" (wall clock spent on the level
    # *in this process*; excluded from resume bit-identity comparisons)
    per_level: Dict[int, Dict[str, float]]
    stats: List[PatternStats]
    elapsed_s: float
    timed_out: bool
    peak_device_bytes: int


@dataclasses.dataclass
class MiningLoopState:
    """The host loop's full carried state at a level boundary.

    This is what the session runtime (`repro.runtime`) snapshots: handing a
    `MiningLoopState` back to `mine()` via hooks resumes the loop exactly
    where it stopped — ``cp`` is the candidate list of the *next* level
    (empty once mining finished, which makes a resumed finished run a
    no-op that just re-materializes the result).
    """

    level: int                          # levels already completed
    cp: List[Pattern]                   # candidates of the next level
    frequent: List[Tuple[Pattern, int]]
    stats: List[PatternStats]
    per_level: Dict[int, Dict[str, float]]
    searched: int
    peak_bytes: int
    elapsed_s: float                    # wall time consumed up to the snapshot
    timed_out: bool = False


def tau_threshold(sigma: int, lam: float, n_vertices: int) -> int:
    """Paper Eq. (1): τ = ⌊σ(1 − 1/n)λ + σ/n⌋, clamped to ≥ 1."""
    n = max(n_vertices, 1)
    return max(1, math.floor(sigma * (1.0 - 1.0 / n) * lam + sigma / n))


def initial_candidates(g: DataGraph) -> List[Pattern]:
    """CP ← EDGES(G): the size-2 patterns actually present in the graph."""
    src = np.repeat(np.arange(g.n), np.diff(g.out_indptr))
    dst = g.out_indices
    la, lb = g.labels[src], g.labels[dst]
    pairs = np.unique(np.stack([la, lb], axis=1), axis=0) if src.size else np.zeros((0, 2), int)
    # reciprocated label pairs (u⇄v exists with these labels)
    rev_keys = set()
    if src.size:
        keys = set(zip(src.tolist(), dst.tolist()))
        mutual = np.array([(s, d) in keys and (d, s) in keys for s, d in zip(src, dst)])
        mpairs = np.unique(np.stack([la[mutual], lb[mutual]], axis=1), axis=0) if mutual.any() else np.zeros((0, 2), int)
        rev_keys = {tuple(p) for p in mpairs.tolist()}
    out: List[Pattern] = []
    for a, b in pairs.tolist():
        adj = np.zeros((2, 2), dtype=bool)
        adj[0, 1] = True
        out.append(Pattern(adj, np.array([a, b], np.int32)))
    for a, b in sorted(rev_keys):
        adj = np.zeros((2, 2), dtype=bool)
        adj[0, 1] = adj[1, 0] = True
        out.append(Pattern(adj, np.array([a, b], np.int32)))
    return dedupe_patterns(out)


def evaluate_pattern(
    host_g: DataGraph,
    dev_g: DeviceGraph,
    pat: Pattern,
    tau: int,
    cfg: MiningConfig,
) -> PatternStats:
    """Metric step for one candidate: stream root blocks until τ or done."""
    mcfg = cfg.match
    plan = make_plan(pat, host_g)
    k = pat.k
    n = host_g.n
    metric = cfg.metric
    early_exit_tau = jnp.int32(np.iinfo(np.int32).max if cfg.complete else tau)

    if metric in ("mis", "mis_luby"):
        state = (mis_lib.bitmap_init(n), jnp.int32(0))
    elif metric == "mni":
        state = metrics_lib.mni_init(k, n)
    elif metric == "frac":
        state = metrics_lib.frac_init(k, n)
    else:  # mis_exact
        state = []

    found_total = 0
    overflowed = False
    blocks = 0
    n_blocks = -(-n // mcfg.root_block)
    for b in range(n_blocks):
        emb, count, found, ovf = match_block(dev_g, plan, jnp.int32(b * mcfg.root_block), mcfg)
        blocks += 1
        found_total += int(found)
        overflowed |= bool(ovf)
        if metric == "mis":
            state = mis_lib.mis_greedy_update(state[0], state[1], emb, count, early_exit_tau, k)
            if not cfg.complete and int(state[1]) >= tau:
                break
        elif metric == "mis_luby":
            state = mis_lib.mis_luby_update(state[0], state[1], emb, count, early_exit_tau, k, n)
            if not cfg.complete and int(state[1]) >= tau:
                break
        elif metric == "mni":
            state = metrics_lib.mni_update(state, emb, count, k)
            if not cfg.complete and int(metrics_lib.mni_value(state)) >= tau:
                break
        elif metric == "frac":
            state = metrics_lib.frac_update(state, emb, count, k)
        else:  # mis_exact — collect embeddings to host
            c = int(count)
            if c:
                state.append(np.asarray(emb[:c]))

    if metric in ("mis", "mis_luby"):
        support = int(state[1])
    elif metric == "mni":
        support = int(metrics_lib.mni_value(state))
    elif metric == "frac":
        support = int(math.floor(float(metrics_lib.frac_value(state))))
    else:
        embs = np.concatenate(state, axis=0) if state else np.zeros((0, k), np.int32)
        support = metrics_lib.exact_mis(embs)

    return PatternStats(
        pattern=pat,
        support=support,
        tau=tau,
        frequent=support >= tau,
        embeddings_found=found_total,
        overflowed=overflowed,
        blocks_run=blocks,
    )


def _device_bytes(cfg: MiningConfig, k: int, n: int) -> int:
    mcfg = cfg.match
    graphless = transient_match_bytes(mcfg, k)
    if cfg.metric in ("mis", "mis_luby"):
        graphless += ((n + 31) // 32) * 4 + (n * 4 if cfg.metric == "mis_luby" else 0)
    elif cfg.metric == "mni":
        graphless += k * n
    elif cfg.metric == "frac":
        graphless += k * n * 4
    return graphless


def mine(g: DataGraph, cfg: MiningConfig, *, hooks=None) -> MiningResult:
    """Algorithm 1.  Returns all frequent patterns + the paper's telemetry.

    ``hooks`` is the session runtime's resume surface (duck-typed; see
    `repro.runtime.session.MiningSession`):

      * ``hooks.loop_resume()`` → Optional[`MiningLoopState`] — restart the
        loop from a level-boundary snapshot instead of from scratch;
      * ``hooks.level_hooks(level)`` → Optional[object] — per-level hooks
        handed to the level executor (mid-level / mid-pattern resume:
        `batched.evaluate_level_batched` / `distributed
        .evaluate_level_distributed` document the surface);
      * ``hooks.on_level_end(MiningLoopState)`` — called at every level
        boundary (and once more, with ``cp=[]``, when mining finishes) with
        the full carried loop state.

    A run resumed from any snapshot produces the same `MiningResult` as the
    uninterrupted run, except wall-clock fields (``elapsed_s``, per-level
    ``wall_s``).
    """
    t0 = time.monotonic()
    dev_g = DeviceGraph.from_host(g)
    graph_bytes = g.nbytes()

    resume = hooks.loop_resume() if hooks is not None else None
    if resume is None:
        frequent: List[Tuple[Pattern, int]] = []
        all_stats: List[PatternStats] = []
        per_level: Dict[int, Dict[str, float]] = {}
        searched = 0
        peak_bytes = graph_bytes
        timed_out = False
        cp = initial_candidates(g)
        level = 0
        elapsed0 = 0.0
    else:
        frequent = list(resume.frequent)
        all_stats = list(resume.stats)
        per_level = dict(resume.per_level)
        searched = resume.searched
        peak_bytes = max(graph_bytes, resume.peak_bytes)
        timed_out = resume.timed_out
        cp = list(resume.cp)
        level = resume.level
        elapsed0 = resume.elapsed_s

    label_universe = sorted(set(g.labels.tolist()))
    searched_keys = {canonical_key(st.pattern) for st in all_stats}
    mis_mode = cfg.metric in ("mis", "mis_luby", "mis_exact")

    use_batched = cfg.execution == "batched" and cfg.metric != "mis_exact"
    use_distributed = cfg.execution == "distributed"
    deadline = (None if cfg.time_limit_s is None
                else t0 + max(cfg.time_limit_s - elapsed0, 0.0))

    def loop_state(next_cp: List[Pattern]) -> MiningLoopState:
        return MiningLoopState(
            level=level, cp=list(next_cp), frequent=list(frequent),
            stats=list(all_stats), per_level=dict(per_level),
            searched=searched, peak_bytes=peak_bytes,
            elapsed_s=elapsed0 + (time.monotonic() - t0),
            timed_out=timed_out)

    while cp:
        level += 1
        level_t0 = time.monotonic()
        level_hooks = hooks.level_hooks(level) if hooks is not None else None
        level_frequent: List[Pattern] = []
        lvl_searched = 0
        lvl_pruned = 0
        lvl_dispatches = 0
        eval_pats: List[Pattern] = []
        eval_taus: List[int] = []
        for pat in cp:
            tau = (
                tau_threshold(cfg.sigma, cfg.lam, pat.k) if mis_mode else cfg.sigma
            )
            # paper §3.1.2 vertex bound: a frequent k-pattern needs k·τ
            # distinct data vertices under the independence property
            if mis_mode and pat.k * tau > g.n:
                lvl_pruned += 1
                continue
            eval_pats.append(pat)
            eval_taus.append(tau)

        if (use_batched or use_distributed) and eval_pats:
            if use_distributed:
                from . import distributed as distributed_lib

                outcomes, lvl_timed_out, tel = distributed_lib.evaluate_level_distributed(
                    g, eval_pats, eval_taus, cfg.match,
                    complete=cfg.complete, deadline=deadline,
                    max_batch=cfg.batch_patterns,
                    blocks_per_super=cfg.blocks_per_super, hooks=level_hooks)
            else:
                outcomes, lvl_timed_out, tel = batched_lib.evaluate_level_batched(
                    g, dev_g, eval_pats, eval_taus, cfg.metric, cfg.match,
                    complete=cfg.complete, deadline=deadline,
                    max_batch=cfg.batch_patterns, hooks=level_hooks)
            timed_out |= lvl_timed_out
            lvl_dispatches += tel.dispatches
            peak_bytes = max(peak_bytes, graph_bytes + tel.state_bytes)
            for pat, tau, out in zip(eval_pats, eval_taus, outcomes):
                if out is None:  # level timed out before this group ran
                    continue
                st = PatternStats(
                    pattern=pat,
                    support=out.support,
                    tau=tau,
                    frequent=out.frequent,
                    embeddings_found=out.embeddings_found,
                    overflowed=out.overflowed,
                    blocks_run=out.blocks_run,
                )
                searched += 1
                lvl_searched += 1
                all_stats.append(st)
                if st.frequent:
                    frequent.append((pat, st.support))
                    level_frequent.append(pat)
        else:
            for pat, tau in zip(eval_pats, eval_taus):
                if deadline is not None and time.monotonic() > deadline:
                    timed_out = True
                    break
                st = evaluate_pattern(g, dev_g, pat, tau, cfg)
                searched += 1
                lvl_searched += 1
                lvl_dispatches += st.blocks_run
                all_stats.append(st)
                peak_bytes = max(peak_bytes, graph_bytes + _device_bytes(cfg, pat.k, g.n))
                if st.frequent:
                    frequent.append((pat, st.support))
                    level_frequent.append(pat)
        per_level[level] = {
            "candidates": len(cp),
            "searched": lvl_searched,
            "pruned": lvl_pruned,
            "frequent": len(level_frequent),
            "dispatches": lvl_dispatches,
            "wall_s": time.monotonic() - level_t0,
        }
        if timed_out or not level_frequent:
            cp = []
        elif (cfg.generation == "merge"
              and level_frequent[0].k + 1 > cfg.max_pattern_size):
            # merge keeps strict level-wise (k−1 → k) discipline
            cp = []
        else:
            if cfg.generation == "merge":
                cp = generate_new_patterns(level_frequent)
            else:
                # edge extension mixes vertex counts (that is the paper's
                # point: same-vertex-count patterns land at different BFS
                # levels)
                cp = edge_extension_candidates(
                    level_frequent, label_universe, max_k=cfg.max_pattern_size
                )
            searched_keys |= {canonical_key(st.pattern) for st in all_stats}
            cp = [
                p for p in cp
                if p.k <= cfg.max_pattern_size and canonical_key(p) not in searched_keys
            ]
        if hooks is not None:
            hooks.on_level_end(loop_state(cp))

    return MiningResult(
        frequent=frequent,
        searched=searched,
        per_level=per_level,
        stats=all_stats,
        elapsed_s=elapsed0 + (time.monotonic() - t0),
        timed_out=timed_out,
        peak_device_bytes=peak_bytes,
    )
