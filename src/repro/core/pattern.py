"""Pattern graphs — the tiny (k ≤ 8) labeled directed graphs FLEXIS mines.

Patterns live on the host as dense boolean adjacency + label vector; the
number of live patterns at any mining level is 10^2–10^4, so host numpy is
the right tool (control plane). Device work never touches these objects —
`plan.py` compiles each pattern into a static matching plan first.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np

__all__ = ["Pattern", "pattern_from_edges", "paper_fig1"]


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A directed, vertex-labeled pattern graph.

    adj[i, j] == True  ⇔  edge i → j.  labels[i] is vertex i's label.
    """

    adj: np.ndarray  # (k, k) bool
    labels: np.ndarray  # (k,) int32

    def __post_init__(self):
        adj = np.asarray(self.adj, dtype=bool)
        labels = np.asarray(self.labels, dtype=np.int32)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError("adj must be square")
        if labels.shape != (adj.shape[0],):
            raise ValueError("labels/adj size mismatch")
        if np.any(np.diag(adj)):
            raise ValueError("self-loops not allowed in patterns")
        object.__setattr__(self, "adj", adj)
        object.__setattr__(self, "labels", labels)

    # -- basic properties ---------------------------------------------------
    @property
    def k(self) -> int:
        return int(self.adj.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum())

    def undirected_adj(self) -> np.ndarray:
        return self.adj | self.adj.T

    def degree(self) -> np.ndarray:
        """Undirected degree per vertex."""
        u = self.undirected_adj()
        return u.sum(axis=0)

    def is_connected(self) -> bool:
        if self.k == 0:
            return True
        u = self.undirected_adj()
        seen = np.zeros(self.k, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            v = stack.pop()
            for w in np.nonzero(u[v])[0]:
                if not seen[w]:
                    seen[w] = True
                    stack.append(int(w))
        return bool(seen.all())

    def is_clique(self) -> bool:
        """Clique in the undirected sense: every vertex pair joined."""
        u = self.undirected_adj()
        return bool(np.all(u | np.eye(self.k, dtype=bool)))

    # -- manipulation --------------------------------------------------------
    def permuted(self, perm: Sequence[int]) -> "Pattern":
        """Return the pattern with vertex i renamed to perm[i].

        new_adj[perm[i], perm[j]] = adj[i, j]; equivalently composing with the
        inverse permutation on both axes.
        """
        perm = np.asarray(perm)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.k)
        return Pattern(self.adj[np.ix_(inv, inv)], self.labels[inv])

    def remove_vertex(self, v: int) -> "Pattern":
        keep = [i for i in range(self.k) if i != v]
        return Pattern(self.adj[np.ix_(keep, keep)], self.labels[keep])

    def add_vertex(
        self, label: int, out_to: Iterable[int] = (), in_from: Iterable[int] = ()
    ) -> "Pattern":
        k = self.k
        adj = np.zeros((k + 1, k + 1), dtype=bool)
        adj[:k, :k] = self.adj
        for j in out_to:
            adj[k, j] = True
        for j in in_from:
            adj[j, k] = True
        return Pattern(adj, np.concatenate([self.labels, [label]]))

    def with_edge(self, i: int, j: int) -> "Pattern":
        adj = self.adj.copy()
        adj[i, j] = True
        return Pattern(adj, self.labels)

    def edges(self) -> List[Tuple[int, int]]:
        return [(int(i), int(j)) for i, j in zip(*np.nonzero(self.adj))]

    # -- identity ------------------------------------------------------------
    def key(self) -> Tuple:
        """Raw (non-canonical) structural key."""
        return (self.k, self.labels.tobytes(), np.packbits(self.adj).tobytes())

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Pattern) and self.key() == other.key()

    def __repr__(self):
        return f"Pattern(k={self.k}, labels={self.labels.tolist()}, edges={self.edges()})"


def pattern_from_edges(
    labels: Sequence[int], edges: Iterable[Tuple[int, int]], *, bidir: bool = False
) -> Pattern:
    labels = np.asarray(labels, dtype=np.int32)
    k = labels.shape[0]
    adj = np.zeros((k, k), dtype=bool)
    for i, j in edges:
        adj[i, j] = True
        if bidir:
            adj[j, i] = True
    return Pattern(adj, labels)


def paper_fig1():
    """The running example of the paper (Figure 1).

    Returns (P1, D_edges, D_labels): pattern P1 = u1-u2-u3 with double arrows
    and labels (A, B, A); data graph D with d1..d4 labeled A, d5..d7 labeled B
    and double-arrow edges d1-d5, d2-d5, d2-d6, d3-d6, d3-d7, d4-d7.
    Ground truth (paper §2.4): MNI = 3, MIS = 2, mIS ∈ {1, 2}.
    """
    A, B = 0, 1
    p1 = pattern_from_edges([A, B, A], [(0, 1), (1, 2)], bidir=True)
    d_labels = [A, A, A, A, B, B, B]  # d1..d4=A, d5..d7=B (0-indexed)
    und = [(0, 4), (1, 4), (1, 5), (2, 5), (2, 6), (3, 6)]
    d_edges = und + [(b, a) for a, b in und]
    return p1, d_edges, d_labels
