"""Run-health report — the fault-tolerance layer's audit trail.

Every recovery the runtime performs silently *changes what happened*
without changing the mined result: a checkpoint restore that fell back
across the COMMIT chain, a transient-EIO save that succeeded on retry, an
overflowed pattern re-run at base cap, a distributed level degraded to the
batched plane.  `RunHealth` is the single place those events land, so a
caller can distinguish "clean run" from "run that recovered" — the results
are bit-identical either way (that is the point), but an operator watching
a mining service needs to see the difference.

The report is carried in `MiningResult.health` and serialized into the
launcher's ``--json`` output.  It is deliberately *excluded* from the
resume bit-identity contract: an interrupted-and-resumed run records the
recoveries it performed; the uninterrupted oracle records none.

Event kinds currently emitted (see docs/architecture.md "Fault
tolerance"):

  * ``save_retry``          — transient I/O error during a snapshot write,
                              retried with backoff and eventually succeeded
  * ``save_async_failure``  — a background checkpoint write died; the error
                              was surfaced (re-raised) to the caller
  * ``restore_fallback``    — the newest snapshot was corrupt/unreadable;
                              restore fell back to an older committed step
  * ``checksum_mismatch``   — a stored array failed its manifest CRC
  * ``overflow_escalation`` — patterns that overflowed an auto-derived cap
                              were re-run at the base cap (exactness pass)
  * ``plane_fallback``      — a distributed level failed and was re-run on
                              the batched plane
  * ``preempted``           — the run was stopped by request after cutting
                              a final committed snapshot
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

__all__ = ["HealthEvent", "RunHealth"]


@dataclasses.dataclass
class HealthEvent:
    """One recovery/degradation/retry, with enough context to act on."""

    kind: str
    detail: str = ""
    step: Optional[int] = None      # checkpoint step, for persistence events
    level: Optional[int] = None     # mining level, for execution events

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "detail": self.detail}
        if self.step is not None:
            d["step"] = int(self.step)
        if self.level is not None:
            d["level"] = int(self.level)
        return d


@dataclasses.dataclass
class RunHealth:
    """Append-only log of every recovery a run performed."""

    events: List[HealthEvent] = dataclasses.field(default_factory=list)

    def record(self, kind: str, detail: str = "", *,
               step: Optional[int] = None,
               level: Optional[int] = None) -> HealthEvent:
        ev = HealthEvent(kind=kind, detail=detail, step=step, level=level)
        self.events.append(ev)
        return ev

    def count(self, kind: str) -> int:
        return sum(1 for ev in self.events if ev.kind == kind)

    @property
    def degraded(self) -> bool:
        """True when anything at all had to be recovered/retried."""
        return bool(self.events)

    def to_dict(self) -> Dict[str, Any]:
        """The ``--json`` schema: events in order plus per-kind counts."""
        counts: Dict[str, int] = {}
        for ev in self.events:
            counts[ev.kind] = counts.get(ev.kind, 0) + 1
        return {
            "degraded": self.degraded,
            "counts": counts,
            "events": [ev.to_dict() for ev in self.events],
        }
