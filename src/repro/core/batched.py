"""Batched-pattern matching — beyond-paper optimization #2 (§Perf).

The paper (and our baseline loop) evaluates candidate patterns one at a
time; but a mining level holds tens-to-hundreds of same-size candidates,
and `match_block` is pure dataflow over *plan arrays* — so an entire level
can be vmapped into ONE device program: plans stack into a leading pattern
axis, the data graph broadcasts, and the mIS bitmaps/counters batch too.

Wins: (CPU) dispatch amortization across candidates; (TPU) one big program
with pattern-level parallelism instead of many small ones — and under
shard_map the pattern axis is a free extra parallelism dimension.

Early exit: patterns that reach τ keep computing until the *block* loop
notices (masked out of the `active` set on the host) — wasted work is at
most one block per finished pattern, repaid many times over by batching.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DataGraph, DeviceGraph
from .pattern import Pattern
from .plan import PatternPlan, make_plan
from .matcher import MatchConfig, match_block
from . import mis as mis_lib

__all__ = ["stack_plans", "batched_mis_supports"]


def stack_plans(plans: Sequence[PatternPlan]) -> PatternPlan:
    """Stack same-k plans into one plan pytree with a leading pattern axis."""
    k = plans[0].k
    assert all(p.k == k for p in plans), "plans must share pattern size"
    leaves = [jax.tree_util.tree_flatten(p)[0] for p in plans]
    treedef = jax.tree_util.tree_flatten(plans[0])[1]
    stacked = [jnp.stack([l[i] for l in leaves]) for i in range(len(leaves[0]))]
    return jax.tree_util.tree_unflatten(treedef, stacked)


@dataclasses.dataclass
class BatchedResult:
    supports: np.ndarray          # (P,) mIS counts (≥ tau ⇒ frequent)
    found: np.ndarray             # (P,) embeddings enumerated
    overflowed: np.ndarray        # (P,) bool


def _batched_block(g: DeviceGraph, plans: PatternPlan, block_start,
                   bitmaps, counts, taus, k: int, cfg: MatchConfig):
    def one(plan, bitmap, count, tau):
        emb, n_valid, found, ovf = match_block(g, plan, block_start, cfg)
        bitmap, count = mis_lib.mis_greedy_update(
            bitmap, count, emb, n_valid, tau, k)
        return bitmap, count, found, ovf

    return jax.vmap(one, in_axes=(0, 0, 0, 0))(plans, bitmaps, counts, taus)


def batched_mis_supports(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    cfg: MatchConfig,
    *,
    complete: bool = False,
) -> BatchedResult:
    """mIS supports for a whole same-k candidate level in batched steps."""
    assert len(patterns) == len(taus) and len(patterns) > 0
    k = patterns[0].k
    assert all(p.k == k for p in patterns)
    P = len(patterns)
    dev_g = DeviceGraph.from_host(host_g)
    plans = stack_plans([make_plan(p, host_g) for p in patterns])
    n = host_g.n

    bitmaps = jnp.zeros((P, (n + 31) // 32), jnp.uint32)
    counts = jnp.zeros((P,), jnp.int32)
    tau_arr = jnp.asarray(
        [np.iinfo(np.int32).max if complete else t for t in taus], jnp.int32)
    found = np.zeros(P, np.int64)
    ovf = np.zeros(P, bool)

    step = jax.jit(_batched_block, static_argnames=("k", "cfg"))
    for b in range(0, n, cfg.root_block):
        bitmaps, counts, blk_found, blk_ovf = step(
            dev_g, plans, jnp.int32(b), bitmaps, counts, tau_arr, k=k,
            cfg=cfg)
        found += np.asarray(blk_found, np.int64)
        ovf |= np.asarray(blk_ovf)
        if not complete and bool((np.asarray(counts) >= np.asarray(taus)).all()):
            break
    return BatchedResult(supports=np.asarray(counts), found=found,
                         overflowed=ovf)
