"""Batched level-wise mining — the default data plane of ``mine()``.

The paper's loop (and our ``execution="sequential"`` oracle) evaluates
candidate patterns one device program at a time; but a mining level holds
tens-to-hundreds of same-size candidates, and ``match_block`` is pure
dataflow over *plan arrays* — so an entire level runs as ONE device program:
plans stack into a leading pattern axis (``plan.stack_plans``), the data
graph broadcasts, ``match_block`` runs under ``vmap``, and the metric state
(mIS bitmaps/counters, MNI image tables, fractional count tables) batches
along the same axis.

Wins: (CPU) dispatch amortization across candidates; (TPU) one big program
with pattern-level parallelism instead of many small ones — and under
shard_map the pattern axis is a free extra parallelism dimension
(``core/distributed.py``).

τ early exit stays *per pattern*: after every root block the host reads the
batched support values, snapshots finished patterns out of the active set,
and — once the active set has halved — re-stacks the survivors into a
smaller power-of-two bucket.  A finished pattern therefore wastes at most
one extra block of masked work (its ``count < τ`` guard freezes all state
updates), repaid many times over by batching; and bucketing bounds
recompilation at log2(P) shapes per (k, geometry).

Per-pattern results are bit-identical to the sequential oracle for the
``mis``, ``mis_luby``, ``mni`` and ``frac`` metrics because each pattern
sees the exact same (block, update) history; ``mis_exact`` (host-side
branch & bound) falls back to the sequential path.  This equivalence is
property-tested in ``tests/core/test_batched_equivalence.py``.

Compiled programs are cached: one executable per (metric, k, match
geometry) python callable (``_step_fn`` below), with XLA's jit cache keying
the remaining shape axes (pattern-bucket size P, graph size).  Levels and
whole mining runs reuse executables instead of re-tracing.

Expansion planes compose transparently: with
``MatchConfig.expansion == "pallas"`` the vmapped ``match_block`` lowers
its fused level kernel with the pattern axis as a leading *grid*
dimension (JAX's Pallas batching rule), so a batched level is still one
kernel launch per expansion level — not P re-entries.  Results stay
bit-identical across (execution plane × expansion plane); see
``docs/architecture.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DataGraph, DeviceGraph
from .pattern import Pattern
from .plan import PatternPlan, make_plan, stack_plans
from .matcher import MatchConfig, match_block, transient_match_bytes
from . import mis as mis_lib
from . import metrics as metrics_lib

__all__ = [
    "BatchedResult", "GroupState", "LevelTelemetry", "PatternOutcome",
    "batched_mis_supports", "collect_pattern_embeddings",
    "evaluate_level_batched", "level_groups",
    "program_cache_stats", "clear_program_cache", "stack_plans",
]

_BATCHABLE_METRICS = ("mis", "mis_luby", "mni", "frac")
# metrics whose sequential loop early-exits on support >= tau
_EARLY_EXIT_METRICS = ("mis", "mis_luby", "mni")

_INT32_MAX = np.iinfo(np.int32).max

# default ceiling on the pattern axis: transient match memory is
# O(P · cap · chunk), so an unbounded level (hundreds of candidates) would
# multiply device footprint by hundreds; 64 keeps the dispatch win while
# bounding memory and the set of compiled bucket shapes.
DEFAULT_MAX_BATCH = 64

# blocks stacked per dispatch by the mis_exact embedding collector — also
# the transient-memory multiplier `flexis._device_bytes` accounts for it
MIS_EXACT_BLOCKS_PER_DISPATCH = 8


# ---------------------------------------------------------------------------
# compiled-program cache: one traced step per (metric, k, match geometry)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _step_fn(metric: str, k: int, cfg: MatchConfig, unbatched: bool = False,
             capture: bool = False):
    """Jitted batched block step for one (metric, k, match geometry).

    Signature of the returned callable:
        step(dev_g, plans, block_start, state, taus)
            -> (state', values, found, overflowed, peaks)
    With ``capture=True`` two more outputs are appended — ``emb`` (P, cap,
    k) int32 and ``n_valid`` (P,) int32, `match_block`'s raw embedding
    table — which the sampled plane records per (pattern, block) so exact
    escalation can *replay* the block instead of re-matching it.

    Shapes/dtypes (P = padded pattern-bucket size, n = graph vertices):
      dev_g:   DeviceGraph pytree (unbatched; broadcasts over P).
      plans:   PatternPlan pytree with a leading P axis on every array
               field (`stack_plans`).
      block_start: () int32 — shared root-block offset.
      state:   metric state, leading P axis —
               mis/mis_luby: ((P, ⌈n/32⌉) uint32 bitmaps, (P,) int32 counts)
               mni: (P, k, n) bool image tables
               frac: (P, k, n) float32 count tables.
      taus:    (P,) int32 device-side freeze guard (mis/mis_luby only).
      values:  (P,) running support — int32 counts/minima, float32 mass.
      found:   (P,) int32 embeddings enumerated this block;
      overflowed: (P,) bool frontier-capacity flags.
      peaks:   (P,) int32 max frontier occupancy inside the block
               (`match_block`'s peak — the planner's cap-sizing signal).

    ``unbatched=True`` compiles the P == 1 bucket *without* the vmap: the
    math is identical (size-1 batch), but XLA fuses the unbatched op chain
    where the degenerate batch dimensions of the vmapped program block
    cross-op fusion on wide ``cap·chunk`` grids — measured ~1.1–1.3×
    on single-pattern compute-bound levels (docs/architecture.md "Why the
    vmapped matcher loses fusion").  Results are bit-identical.
    """

    if metric in ("mis", "mis_luby"):

        def step_one(g, plan, block_start, bm, cnt, tau):
            emb, n_valid, found, ovf, peak = match_block(
                g, plan, block_start, cfg)
            if metric == "mis":
                bm, cnt = mis_lib.mis_greedy_update(
                    bm, cnt, emb, n_valid, tau, k)
            else:
                bm, cnt = mis_lib.mis_luby_update(
                    bm, cnt, emb, n_valid, tau, k, g.n)
            if capture:
                return bm, cnt, found, ovf, peak, emb, n_valid
            return bm, cnt, found, ovf, peak

        def step(g, plans, block_start, state, taus):
            bitmaps, counts = state
            if unbatched:
                squeeze = jax.tree_util.tree_map(lambda a: a[0], plans)
                out = step_one(
                    g, squeeze, block_start, bitmaps[0], counts[0], taus[0])
                bm, cnt = out[0], out[1]
                rest = tuple(x[None] for x in out[2:])
                return ((bm[None], cnt[None]), cnt[None]) + rest
            out = jax.vmap(
                lambda plan, bm, cnt, tau: step_one(
                    g, plan, block_start, bm, cnt, tau))(
                plans, bitmaps, counts, taus)
            bitmaps, counts = out[0], out[1]
            return ((bitmaps, counts), counts) + tuple(out[2:])

    elif metric in ("mni", "frac"):

        def step_one(g, plan, block_start, table):
            emb, n_valid, found, ovf, peak = match_block(
                g, plan, block_start, cfg)
            if metric == "mni":
                table = metrics_lib.mni_update(table, emb, n_valid, k)
                value = metrics_lib.mni_value(table)
            else:
                table = metrics_lib.frac_update(table, emb, n_valid, k)
                value = metrics_lib.frac_value(table)
            if capture:
                return table, value, found, ovf, peak, emb, n_valid
            return table, value, found, ovf, peak

        def step(g, plans, block_start, state, taus):
            del taus  # MNI/frac need no device-side τ; the host owns early exit
            if unbatched:
                squeeze = jax.tree_util.tree_map(lambda a: a[0], plans)
                out = step_one(g, squeeze, block_start, state[0])
                return tuple(x[None] for x in out)
            out = jax.vmap(
                lambda plan, table: step_one(g, plan, block_start, table))(
                plans, state)
            return out

    else:
        raise ValueError(f"metric {metric!r} has no batched step")

    return jax.jit(step)


@functools.lru_cache(maxsize=None)
def _replay_step_fn(metric: str, k: int, n: int):
    """Jitted update-only block step — escalation's replay of a recorded
    sample block.

    Signature: ``step(state, emb, n_valid, taus) -> (state', values)`` with
    ``emb`` (P, cap, k) int32 / ``n_valid`` (P,) int32 being a recorded
    `match_block` output.  Applies exactly the metric update the full step
    would have applied — same embedding rows, same order, same device-side
    τ guard — without re-running the expansion grid, so a replayed block's
    metric state transition is bit-identical to the matched one.
    """

    if metric in ("mis", "mis_luby"):

        def step_one(emb, n_valid, bm, cnt, tau):
            if metric == "mis":
                return mis_lib.mis_greedy_update(bm, cnt, emb, n_valid,
                                                 tau, k)
            return mis_lib.mis_luby_update(bm, cnt, emb, n_valid, tau, k, n)

        def step(state, emb, n_valid, taus):
            bitmaps, counts = jax.vmap(step_one)(emb, n_valid, *state, taus)
            return (bitmaps, counts), counts

    elif metric in ("mni", "frac"):

        def step_one(emb, n_valid, table):
            if metric == "mni":
                table = metrics_lib.mni_update(table, emb, n_valid, k)
                return table, metrics_lib.mni_value(table)
            table = metrics_lib.frac_update(table, emb, n_valid, k)
            return table, metrics_lib.frac_value(table)

        def step(state, emb, n_valid, taus):
            del taus
            return jax.vmap(step_one)(emb, n_valid, state)

    else:
        raise ValueError(f"metric {metric!r} has no replay step")

    return jax.jit(step)


def _replay_arrays(replay, bucket_map: np.ndarray, b: int, cap: int, k: int):
    """Assemble one replayed block's device inputs + host accounting.

    ``replay`` is the group's per-pattern replay table (group index →
    {schedule position → {"emb", "found", "ovf", "peak"}}).  Pad rows
    (bucket_map == −1) get empty embeddings — their τ guard is 0 and their
    accounting rows are dead, exactly like pad rows of a matched step.
    """
    P = int(bucket_map.size)
    emb = np.full((P, cap, k), -1, np.int32)
    nv = np.zeros(P, np.int32)
    found = np.zeros(P, np.int32)
    ovf = np.zeros(P, bool)
    peak = np.zeros(P, np.int32)
    for row in range(P):
        gi = int(bucket_map[row])
        if gi < 0:
            continue
        rec = replay[gi][b]
        rows = np.asarray(rec["emb"], np.int32).reshape(-1, k)
        c = int(rows.shape[0])
        if c:
            emb[row, :c] = rows
        nv[row] = c
        found[row] = int(rec["found"])
        ovf[row] = bool(rec["ovf"])
        peak[row] = int(rec["peak"])
    return emb, nv, found, ovf, peak


def program_cache_stats():
    """lru_cache stats of the batched step-program cache (hits = executable
    reuse across levels/runs; misses = distinct (metric, k, geometry))."""
    return _step_fn.cache_info()


def clear_program_cache() -> None:
    _step_fn.cache_clear()


# ---------------------------------------------------------------------------
# batched metric state
# ---------------------------------------------------------------------------

def _state_init(metric: str, P: int, k: int, n: int):
    """Zeroed metric state with a leading P pattern axis (see `_step_fn`)."""
    if metric in ("mis", "mis_luby"):
        return (jnp.zeros((P, mis_lib.bitmap_words(n)), jnp.uint32),
                jnp.zeros((P,), jnp.int32))
    if metric == "mni":
        return jnp.zeros((P, k, n), jnp.bool_)
    if metric == "frac":
        return jnp.zeros((P, k, n), jnp.float32)
    raise ValueError(metric)


def _state_bytes(metric: str, k: int, n: int) -> int:
    """Per-pattern metric-state footprint (telemetry)."""
    if metric in ("mis", "mis_luby"):
        return mis_lib.bitmap_words(n) * 4 + 4 + (n * 4 if metric == "mis_luby" else 0)
    if metric == "mni":
        return k * n
    if metric == "frac":
        return k * n * 4
    return 0


def _gather_rows(tree, sel: np.ndarray):
    idx = jnp.asarray(sel, jnp.int32)
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def _bucket_size(n_active: int) -> int:
    return max(1, 1 << max(0, math.ceil(math.log2(max(n_active, 1)))))


# ---------------------------------------------------------------------------
# level executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PatternOutcome:
    """Per-pattern result of a batched level — mirrors the sequential
    ``evaluate_pattern`` outputs field-for-field."""
    support: int
    frequent: bool
    embeddings_found: int
    overflowed: bool
    blocks_run: int
    # max frontier occupancy observed over the blocks this pattern ran
    # (post-clip, ≤ cap) — the planner's per-level cap-sizing input
    max_count: int = 0
    # sampled plane only: True when `support` is a Horvitz–Thompson
    # estimate (clamped below τ) rather than an exact count — every exact
    # plane, and every escalated pattern, reports False
    estimated: bool = False


@dataclasses.dataclass
class BatchedResult:
    """Level result arrays aligned with the input pattern list (length P₀ =
    number of requested patterns, NOT the padded device bucket size)."""

    supports: np.ndarray          # (P₀,) int64 metric supports (≥ tau ⇒ frequent)
    found: np.ndarray             # (P₀,) int64 embeddings enumerated
    overflowed: np.ndarray        # (P₀,) bool


@dataclasses.dataclass
class LevelTelemetry:
    """Aggregate accounting of one level-executor call."""

    state_bytes: int = 0          # peak transient device state (pattern axis)
    dispatches: int = 0           # device program invocations
    max_count: int = 0            # peak frontier occupancy across patterns
    overflowed: bool = False      # any pattern hit the frontier cap
    # per-root-block peak frontier occupancy, indexed by block id (int64,
    # length ⌈n/root_block⌉) — the sampled plane's occupancy weights for
    # the next level's block draw (`core/sampled.py`)
    block_peaks: Optional[np.ndarray] = None
    # within-level replans: how many times `_mine_group` re-derived its cap
    # geometry at a shrink boundary (auto plane only; see ``replan``)
    replans: int = 0
    # sampled-plane summary (fraction, escalations, CI widths); None on
    # the other planes — `mine()` records it as per_level["sampled"]
    sampled: Optional[dict] = None


@dataclasses.dataclass
class GroupState:
    """Carried state of one in-flight same-k group, snapshotted per block.

    This is the batched plane's resume unit: everything `_mine_group` needs
    to continue from root block ``next_block`` — the (possibly re-stacked)
    active-set ``bucket_map``, the device metric state for the current
    bucket (kept as device arrays here; the session serializes them to
    logical host arrays only when it actually persists a snapshot), and the
    per-pattern host accumulators for the whole group (P₀-aligned).
    """

    next_block: int               # next schedule position (block-order index)
    bucket_map: np.ndarray        # (P_pad,) int — group index per row, -1 pad
    state: object                 # device metric state, leading P_pad axis
    supports: np.ndarray          # (P₀,) int64
    found: np.ndarray             # (P₀,) int64
    overflowed: np.ndarray        # (P₀,) bool
    blocks_run: np.ndarray        # (P₀,) int64
    dispatches: int = 0
    max_count: Optional[np.ndarray] = None   # (P₀,) int64 peak occupancy
    # per-block peak occupancy by block id (see LevelTelemetry.block_peaks);
    # carried so a resumed group reports identical occupancy telemetry
    block_peaks: Optional[np.ndarray] = None
    # within-level replanning (auto plane): the group's *current* frontier
    # cap and how many times it was re-derived — carried so a resumed
    # group continues with the identical (possibly shrunk) geometry
    cap: Optional[int] = None
    replans: int = 0


def level_groups(patterns: Sequence[Pattern], max_batch: int):
    """Deterministic (k, slice-offset, indices) schedule of a level.

    Shared by the batched and distributed level executors — and by the
    session runtime, whose mid-level cursor is the (k, lo) pair — so a
    resumed level re-derives the exact same grouping.
    """
    groups: dict = {}
    for i, p in enumerate(patterns):
        groups.setdefault(p.k, []).append(i)
    for k in sorted(groups):
        for lo in range(0, len(groups[k]), max_batch):
            yield k, lo, groups[k][lo:lo + max_batch]


def _mine_group(
    dev_g: DeviceGraph,
    plans: List[PatternPlan],
    taus: Sequence[int],
    metric: str,
    cfg: MatchConfig,
    *,
    complete: bool,
    n: int,
    deadline: Optional[float] = None,
    resume: Optional[GroupState] = None,
    on_block=None,
    block_order: Optional[np.ndarray] = None,
    replay: Optional[List[dict]] = None,
    emb_sink=None,
    replan: bool = False,
    counters: Optional[dict] = None,
) -> Tuple[List[Optional[PatternOutcome]], bool, int, np.ndarray, int]:
    """Run one same-k candidate group level-wise; returns
    (outcomes, timed_out, dispatches, block_peaks, replans).

    ``replay`` (escalation reuse): per-pattern tables {schedule position →
    {"emb", "found", "ovf", "peak"}} recorded by the sample pass.  At a
    schedule position every live pattern has a record for, the loop applies
    the recorded embeddings through `_replay_step_fn` — the identical
    metric update, minus the expansion grid — instead of re-matching the
    block.  ``emb_sink(b, emb, n_valid, found, ovf, peak, bucket_map)`` is
    the recording side: when set, steps run in capture mode and the raw
    `match_block` outputs stream to the callback per block.

    ``replan=True`` (auto plane only) re-derives the frontier cap at
    shrink-re-stack boundaries: when the live survivors' observed peak
    occupancy fits a smaller cap with `planner.CAP_HEADROOM`× headroom
    (never below `planner.CAP_FLOOR`, and never once any live pattern has
    overflowed), the remaining blocks run at the shrunk geometry.  The
    current cap and replan count ride in `GroupState` so resumes continue
    bit-identically; `flexis.mine` re-checks overflow against the full
    config cap, so a replan that shrinks too far only costs an escalation.

    ``counters`` (optional dict) accumulates {"match_blocks",
    "replay_blocks"} — the dispatch/block accounting the escalation-reuse
    tests assert on.

    ``block_order`` is the static root-block schedule — a permutation of
    block ids from `planner.root_block_order` (None = vertex-id order), or
    a *subset* of one: the sampled plane (`core/sampled.py`) passes only
    its drawn blocks, and the loop runs exactly the schedule it is given.
    The loop cursor — including `GroupState.next_block` — indexes into
    the *schedule*, so a resumed run walks the identical permutation.
    ``block_peaks`` maps block id → peak frontier occupancy over the
    group's still-live patterns at that block (0 for blocks not run).

    Per-pattern histories reproduce the sequential loop exactly: a pattern
    accumulates (found, overflowed, blocks) for precisely the block prefix the
    sequential loop would have run, and its support is snapshotted at the
    block where it crosses τ (or at the end, for complete runs).

    On a timeout, only patterns that *finished* (reached τ, or ran every
    block) get an outcome; still-in-flight patterns return ``None`` — the
    sequential loop's all-or-nothing timeout contract, where a pattern is
    either fully evaluated or not reported at all.

    ``resume`` continues a previously snapshotted `GroupState` (its plans
    bucket is re-derived from ``plans`` + the saved active-set map — pad
    rows may rebind to a different plan, which is unobservable: their τ
    guard is 0 and their accounting rows are dead); ``on_block`` is called
    with the carried `GroupState` after every block that leaves the group
    still in flight.  Continuation is bit-identical: the per-pattern
    (block, update) history of a resumed run equals the uninterrupted one.
    """
    P0 = len(plans)
    k = plans[0].k
    early_exit = (not complete) and metric in _EARLY_EXIT_METRICS

    taus_np = np.asarray(taus, np.int64)
    # device-side τ guard: freeze mis counters at τ unless complete
    dev_tau_full = np.full(P0, _INT32_MAX if complete else 0, np.int32)
    if not complete:
        dev_tau_full[:] = np.minimum(taus_np, _INT32_MAX)

    def bucket_taus(bucket_map: np.ndarray) -> jnp.ndarray:
        safe = np.where(bucket_map >= 0, bucket_map, 0)
        return jnp.asarray(
            np.where(bucket_map >= 0, dev_tau_full[safe], 0), jnp.int32)

    total_blocks = -(-n // cfg.root_block)
    if resume is None:
        supports = np.zeros(P0, np.int64)
        found = np.zeros(P0, np.int64)
        ovf = np.zeros(P0, bool)
        blocks_run = np.zeros(P0, np.int64)
        max_count = np.zeros(P0, np.int64)
        block_peaks = np.zeros(total_blocks, np.int64)
        # current bucket: stacked plans + state + map to group idx (-1 = pad)
        P_pad = _bucket_size(P0)
        bucket_map = np.concatenate([np.arange(P0), np.full(P_pad - P0, -1)])
        state = _state_init(metric, P_pad, k, n)
        start_block = 0
        dispatches = 0
    else:
        supports = resume.supports.astype(np.int64).copy()
        found = resume.found.astype(np.int64).copy()
        ovf = resume.overflowed.astype(bool).copy()
        blocks_run = resume.blocks_run.astype(np.int64).copy()
        max_count = (np.zeros(P0, np.int64) if resume.max_count is None
                     else resume.max_count.astype(np.int64).copy())
        block_peaks = (np.zeros(total_blocks, np.int64)
                       if resume.block_peaks is None
                       else resume.block_peaks.astype(np.int64).copy())
        bucket_map = np.asarray(resume.bucket_map, np.int64).copy()
        state = jax.tree_util.tree_map(jnp.asarray, resume.state)
        start_block = int(resume.next_block)
        dispatches = int(resume.dispatches)
    replans = 0 if resume is None else int(getattr(resume, "replans", 0))
    if resume is not None and resume.cap is not None \
            and int(resume.cap) != cfg.cap:
        # continue at the geometry the killed process had replanned to
        cfg = dataclasses.replace(cfg, cap=int(resume.cap))
    plans_cur = _gather_rows(stack_plans(plans),
                             np.where(bucket_map >= 0, bucket_map, 0))
    taus_dev = bucket_taus(bucket_map)

    timed_out = False
    unfinished: set = set()
    if block_order is None:
        block_order = np.arange(total_blocks, dtype=np.int64)
    # the schedule may be a subset (sampled plane): the loop length is the
    # schedule's, not the graph's
    n_blocks = int(block_order.shape[0])
    # positions every live pattern can replay (escalation reuse) — the
    # sample pass drew level-wide, so escalated patterns share one set
    replay_at = (set(replay[0].keys()) if replay else set())
    rstep = _replay_step_fn(metric, k, n) if replay_at else None
    # the P=1 bucket compiles without the vmap (fusion win, bit-identical);
    # re-resolved only when a shrink re-stack changes the bucket width
    capture = emb_sink is not None
    step = _step_fn(metric, k, cfg, unbatched=bucket_map.size == 1,
                    capture=capture)
    for b in range(start_block, n_blocks):
        if deadline is not None and time.monotonic() > deadline:
            timed_out = True
            unfinished = {int(i) for i in bucket_map[bucket_map >= 0]}
            break
        if b in replay_at:
            emb_np, nv_np, found_np, ovf_np, peak_np = _replay_arrays(
                replay, bucket_map, b, cfg.cap, k)
            state, values = rstep(
                state, jnp.asarray(emb_np), jnp.asarray(nv_np), taus_dev)
            values_np = np.asarray(values)
            if counters is not None:
                counters["replay_blocks"] = counters.get(
                    "replay_blocks", 0) + 1
        else:
            out = step(
                dev_g, plans_cur,
                jnp.int32(int(block_order[b]) * cfg.root_block), state,
                taus_dev)
            state, values, blk_found, blk_ovf, blk_peak = out[:5]
            values_np = np.asarray(values)
            found_np = np.asarray(blk_found)
            ovf_np = np.asarray(blk_ovf)
            peak_np = np.asarray(blk_peak)
            if capture:
                emb_sink(b, np.asarray(out[5]), np.asarray(out[6]),
                         found_np, ovf_np, peak_np, bucket_map)
            if counters is not None:
                counters["match_blocks"] = counters.get(
                    "match_blocks", 0) + 1
        dispatches += 1

        live = bucket_map >= 0
        gi = bucket_map[live]
        found[gi] += found_np[live].astype(np.int64)
        ovf[gi] |= ovf_np[live]
        blocks_run[gi] += 1
        max_count[gi] = np.maximum(max_count[gi],
                                   peak_np[live].astype(np.int64))
        bid = int(block_order[b])
        block_peaks[bid] = max(block_peaks[bid],
                               int(peak_np[live].max(initial=0)))
        if metric == "frac":
            supports[gi] = np.floor(values_np[live].astype(np.float64)).astype(np.int64)
        else:
            supports[gi] = values_np[live].astype(np.int64)

        if early_exit:
            still = gi[supports[gi] < taus_np[gi]]
            if still.size == 0:
                break
            if still.size <= bucket_map.size // 2 and b + 1 < n_blocks:
                # shrink: re-stack survivors into the next power-of-two bucket
                pos_of = {g_idx: i for i, g_idx in enumerate(bucket_map)}
                pos = np.array([pos_of[g_idx] for g_idx in still])
                pad = _bucket_size(still.size) - still.size
                sel = np.concatenate([pos, np.full(pad, pos[0])]).astype(np.int64)
                plans_cur = _gather_rows(plans_cur, sel)
                state = _gather_rows(state, sel)
                bucket_map = np.concatenate([still, np.full(pad, -1)])
                taus_dev = bucket_taus(bucket_map)
                if replan and not ovf[still].any():
                    # within-level replanning: the survivors' measured peak
                    # may fit a much smaller frontier cap — re-derive it
                    # with the planner's headroom/floor rails (never once a
                    # live pattern has overflowed: truncation is the only
                    # cap-dependent behaviour and it must stay flagged)
                    from .planner import CAP_FLOOR, CAP_HEADROOM
                    live_peak = int(max_count[still].max())
                    if live_peak > 0:
                        new_cap = min(cfg.cap,
                                      max(_bucket_size(CAP_HEADROOM
                                                       * live_peak),
                                          CAP_FLOOR))
                        if new_cap < cfg.cap:
                            cfg = dataclasses.replace(cfg, cap=new_cap)
                            replans += 1
                step = _step_fn(metric, k, cfg,
                                unbatched=bucket_map.size == 1,
                                capture=capture)
            elif still.size < gi.size:
                # same bucket; just stop accounting for the finished patterns
                bucket_map = np.where(np.isin(bucket_map, still), bucket_map, -1)

        if on_block is not None and b + 1 < n_blocks:
            on_block(GroupState(
                next_block=b + 1, bucket_map=bucket_map.copy(), state=state,
                supports=supports.copy(), found=found.copy(),
                overflowed=ovf.copy(), blocks_run=blocks_run.copy(),
                dispatches=dispatches, max_count=max_count.copy(),
                block_peaks=block_peaks.copy(), cap=int(cfg.cap),
                replans=replans))

    outcomes: List[Optional[PatternOutcome]] = [
        None if i in unfinished else PatternOutcome(
            support=int(supports[i]),
            frequent=bool(supports[i] >= taus_np[i]),
            embeddings_found=int(found[i]),
            overflowed=bool(ovf[i]),
            blocks_run=int(blocks_run[i]),
            max_count=int(max_count[i]),
        )
        for i in range(P0)
    ]
    return outcomes, timed_out, dispatches, block_peaks, replans


def evaluate_level_batched(
    host_g: DataGraph,
    dev_g: DeviceGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    metric: str,
    cfg: MatchConfig,
    *,
    complete: bool = False,
    deadline: Optional[float] = None,
    max_batch: int = DEFAULT_MAX_BATCH,
    hooks=None,
    block_order: Optional[np.ndarray] = None,
    replay: Optional[List[dict]] = None,
    replan: bool = False,
    counters: Optional[dict] = None,
) -> Tuple[List[Optional[PatternOutcome]], bool, LevelTelemetry]:
    """Evaluate a whole candidate level with the batched data plane.

    ``replay``/``replan``/``counters`` thread through to `_mine_group`
    (escalation reuse, within-level replanning, block accounting — see its
    docstring); ``replay`` aligns with ``patterns`` and is sliced per
    group.

    Args:
      host_g/dev_g: the data graph and its device mirror.
      patterns: sequence of `Pattern` (sizes may mix — edge-extension
        generation); taus: same-length int thresholds.
      metric: one of ``("mis", "mis_luby", "mni", "frac")``.
      cfg: `MatchConfig` — both its execution geometry and its
        ``expansion`` plane apply to every pattern of the level.
      complete: disable τ early exit (exact metric values).
      deadline: ``time.monotonic()`` cutoff; max_batch: pattern-axis cap.
      hooks: optional level-hooks object (the session runtime's resume
        surface; see `repro.runtime.session`).  Duck-typed methods —
        ``resume_outcomes()``: {pattern index → `PatternOutcome`} already
        computed by a previous process (a group is skipped iff every one of
        its indices is present); ``resume_dispatches()``: device dispatches
        already spent on the skipped groups (keeps level telemetry
        identical across a resume); ``resume_block_peaks()`` (optional):
        the per-block occupancy peaks those groups recorded, or None;
        ``group_resume(k, lo)``: the in-flight `GroupState` for one group,
        or None; ``on_group_state(k, lo, group_state)``: called after every
        block of an unfinished group; ``on_group_done(k, lo, idxs,
        outcomes, dispatches, block_peaks=None)``: called when a group
        completes.

    Candidates are grouped by k — and each group split into ≤ ``max_batch``
    slices to bound transient device memory (peak transient is
    ``bucket_size(P) · (state + transient_match_bytes)``) — with each slice
    running as one vmapped program.  Returns (outcomes aligned with the
    input — ``None`` for candidates not reached before a timeout —,
    timed_out, `LevelTelemetry`).
    """
    assert len(patterns) == len(taus)
    assert metric in _BATCHABLE_METRICS, metric
    assert max_batch >= 1
    outcomes: List[Optional[PatternOutcome]] = [None] * len(patterns)
    prefilled = hooks.resume_outcomes() if hooks is not None else None

    timed_out = False
    telemetry = LevelTelemetry()
    peaks = np.zeros(-(-host_g.n // cfg.root_block), np.int64)
    if hooks is not None:
        telemetry.dispatches = int(hooks.resume_dispatches())
        rbp = getattr(hooks, "resume_block_peaks", None)
        done_peaks = rbp() if rbp is not None else None
        if done_peaks is not None:
            peaks = np.maximum(peaks, np.asarray(done_peaks, np.int64))
        rr = getattr(hooks, "resume_replans", None)
        if rr is not None:
            telemetry.replans = int(rr())
    for k, lo, idxs in level_groups(patterns, max_batch):
        # state_bytes is pure arithmetic — account skipped groups too, so a
        # resumed level reports the same peak as the uninterrupted one
        telemetry.state_bytes = max(
            telemetry.state_bytes,
            _bucket_size(len(idxs))
            * (_state_bytes(metric, k, host_g.n)
               + transient_match_bytes(cfg, k)))
        if prefilled is not None and all(i in prefilled for i in idxs):
            for i in idxs:
                outcomes[i] = prefilled[i]
            continue
        plans = [make_plan(patterns[i], host_g) for i in idxs]
        group_taus = [taus[i] for i in idxs]
        resume = hooks.group_resume(k, lo) if hooks is not None else None
        on_block = (functools.partial(hooks.on_group_state, k, lo)
                    if hooks is not None else None)
        group_replay = None if replay is None else [replay[i] for i in idxs]
        got, group_timed_out, dispatches, group_peaks, group_replans = \
            _mine_group(
                dev_g, plans, group_taus, metric, cfg,
                complete=complete, n=host_g.n, deadline=deadline,
                resume=resume, on_block=on_block, block_order=block_order,
                replay=group_replay, replan=replan, counters=counters)
        telemetry.dispatches += dispatches
        telemetry.replans += group_replans
        peaks = np.maximum(peaks, group_peaks)
        for i, out in zip(idxs, got):
            outcomes[i] = out
        if hooks is not None and not group_timed_out:
            hooks.on_group_done(k, lo, idxs, got, dispatches,
                                block_peaks=[int(x) for x in group_peaks],
                                replans=group_replans)
        if group_timed_out:
            timed_out = True
            break
    assert timed_out or all(o is not None for o in outcomes)
    telemetry.block_peaks = peaks
    for o in outcomes:
        if o is not None:
            telemetry.max_count = max(telemetry.max_count, o.max_count)
            telemetry.overflowed |= o.overflowed
    return outcomes, timed_out, telemetry


# ---------------------------------------------------------------------------
# batched embedding collection (mis_exact's device half)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _collect_fn(k: int, cfg: MatchConfig):
    """Jitted embedding collector: `match_block` vmapped over a *blocks*
    axis — (B,) block starts in, ((B, cap, k) emb, (B,) count/found/ovf/peak)
    out.  One program per (k, geometry, B); B is bucketed by the caller."""

    def collect(g, plan, starts):
        return jax.vmap(lambda s: match_block(g, plan, s, cfg))(starts)

    return jax.jit(collect)


def collect_pattern_embeddings(
    dev_g: DeviceGraph,
    plan: PatternPlan,
    cfg: MatchConfig,
    n: int,
    *,
    block_order: Optional[np.ndarray] = None,
    blocks_per_dispatch: int = MIS_EXACT_BLOCKS_PER_DISPATCH,
) -> Tuple[np.ndarray, int, bool, int, int, int]:
    """Enumerate EVERY block's embeddings for one pattern, batched on device.

    The device half of ``mis_exact``: instead of one dispatch per root
    block (the pre-planner sequential loop), blocks stack on a vmapped
    leading axis — ``blocks_per_dispatch`` per program — and only the
    branch-and-bound MIS solve stays on host.  Tail dispatches pad with
    ``block_start = n`` (matches no roots), so results are independent of
    the dispatch width.

    Returns (embeddings (m, k) int32 in schedule order, found, overflowed,
    blocks_run, max_count, dispatches) — field-for-field what the
    per-block sequential loop accumulated, because each block's
    (emb, count) is unchanged and exact MIS is invariant to embedding
    order anyway.
    """
    assert blocks_per_dispatch >= 1
    n_blocks = -(-n // cfg.root_block)
    if block_order is None:
        block_order = np.arange(n_blocks, dtype=np.int64)
    assert block_order.shape[0] == n_blocks
    collect = _collect_fn(plan.k, cfg)

    chunks: List[np.ndarray] = []
    found_total = 0
    overflowed = False
    max_count = 0
    dispatches = 0
    for lo in range(0, n_blocks, blocks_per_dispatch):
        ids = block_order[lo: lo + blocks_per_dispatch]
        pad = blocks_per_dispatch - ids.shape[0]
        starts = np.concatenate(
            [ids * cfg.root_block, np.full(pad, n, np.int64)])
        emb, count, found, ovf, peak = collect(
            dev_g, plan, jnp.asarray(starts, jnp.int32))
        dispatches += 1
        counts = np.asarray(count)
        valid = ids.shape[0]
        found_total += int(np.asarray(found)[:valid].sum())
        overflowed |= bool(np.asarray(ovf)[:valid].any())
        max_count = max(max_count, int(np.asarray(peak)[:valid].max()))
        emb_np = None
        for j in range(valid):
            c = int(counts[j])
            if c:
                if emb_np is None:
                    emb_np = np.asarray(emb)
                chunks.append(emb_np[j, :c])
    embs = (np.concatenate(chunks, axis=0) if chunks
            else np.zeros((0, plan.k), np.int32))
    return embs, found_total, overflowed, n_blocks, max_count, dispatches


# ---------------------------------------------------------------------------
# legacy convenience API (kept for callers/tests of the original sketch)
# ---------------------------------------------------------------------------

def batched_mis_supports(
    host_g: DataGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    cfg: MatchConfig,
    *,
    complete: bool = False,
) -> BatchedResult:
    """mIS supports for a whole same-k candidate level in batched steps.

    patterns/taus: same-length sequences; returns a `BatchedResult` whose
    arrays align with the input order (see the class docstring).  Runs the
    full level to completion unless per-pattern τ early exit applies.
    """
    assert len(patterns) == len(taus) and len(patterns) > 0
    dev_g = DeviceGraph.from_host(host_g)
    outcomes, _, _ = evaluate_level_batched(
        host_g, dev_g, patterns, taus, "mis", cfg, complete=complete)
    return BatchedResult(
        supports=np.asarray([o.support for o in outcomes], np.int64),
        found=np.asarray([o.embeddings_found for o in outcomes], np.int64),
        overflowed=np.asarray([o.overflowed for o in outcomes], bool),
    )
