"""Sampled data plane — bounded-error support estimation with exact escalation.

FLEXIS's τ early exit makes every answer exact but still pays full
root-block coverage for *infrequent* patterns (they never cross τ, so they
run every block).  FS³-style sampling inverts that cost: run each
candidate over a weighted sample of root blocks, extrapolate its support
with a Horvitz–Thompson-style estimator, and only spend full coverage on
patterns whose confidence interval cannot rule τ in or out.

The plane's contract (``execution="sampled"``, ``escalate=True``):

  * **sample pass** — the planner draws ``n_sample`` schedule positions
    without replacement (systematic PPS: inclusion probabilities exactly
    ``min(1, s·p_i)``), weighted by the previous level's per-block frontier
    occupancy (``block_peaks`` telemetry) with degree-ordered fallback
    weights at k = 2.  The pass runs `_mine_group` in *complete* mode over
    the sampled blocks only, recording each pattern's per-block support
    increments;
  * **classify** — per pattern, a HT estimate plus a normal-approximation
    confidence interval from the increment variance.  Patterns whose whole
    interval sits below τ are *pruned*: reported infrequent with an
    ``estimated=True`` outcome (support clamped to τ−1).  Everything else
    — interval straddling τ or above it — **escalates**;
  * **escalate** — the escalated subset re-runs on the exact batched plane
    from block 0 over the full schedule with real τ early exit.  Because
    per-pattern batched results are bucket-composition-independent (the
    batched ≡ sequential contract), every escalated pattern's outcome is
    bit-identical to the forced-batched oracle's — so the frequent set,
    its supports, and the whole level trajectory match the oracle exactly;
    only pruned (truly infrequent) patterns carry estimates.

Fraction 1.0 (or ``complete=True``) degenerates to the exact batched plane
over the full schedule — zero escalations, bit-identical everything.

Statistical machinery (`normal_quantile`, `systematic_sample`,
`ht_interval`) is pure and host-side; the RNG chain is counter-based
(Philox keyed on ``(sample_seed, level)``), recorded in the level plan and
replayed verbatim on resume, so a killed run re-draws the identical sample.
Property tests: ``tests/core/test_sampled.py``.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batched import (
    DEFAULT_MAX_BATCH, LevelTelemetry, PatternOutcome, _bucket_size,
    _mine_group, _state_bytes, evaluate_level_batched, level_groups,
)
from .graph import DataGraph, DeviceGraph
from .matcher import MatchConfig, transient_match_bytes
from .pattern import Pattern
from .plan import make_plan

__all__ = [
    "evaluate_level_sampled", "ht_estimate", "ht_interval",
    "inclusion_probs", "normal_quantile", "sample_key", "sample_uniform",
    "systematic_sample",
]

# near-certain inclusion: treat π within fp-noise of 1 as a certainty unit
_CERTAIN = 1.0 - 1e-9


# ---------------------------------------------------------------------------
# pure statistical machinery
# ---------------------------------------------------------------------------

def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    |error| < 1.2e-9 over (0, 1) — far below the CI slack the escalation
    rule tolerates — with no scipy dependency.
    """
    if not 0.0 < p < 1.0:
        raise ValueError("p must be in (0, 1)")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    p_low, p_high = 0.02425, 1 - 0.02425
    if p < p_low:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
                + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > p_high:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
                 + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q
                            + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
            + a[5]) * q / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                            + b[4]) * r + 1)


def sample_key(seed: int, level: int) -> List[int]:
    """The level's RNG key — explicit, recorded, replayed on resume."""
    return [int(seed), int(level)]


def sample_uniform(key: Sequence[int], count: int = 1) -> float:
    """The ``count``-th uniform in [0, 1) from a counter-based (Philox) key.

    Counter-based so the draw depends only on the key words — identical
    across platforms, processes, and resumes.  ``count`` indexes into the
    key's stream (1 = the first value, the default): adaptive round ``r``
    consumes the ``(r+1)``-th value, so every round's uniform is a pure
    function of (key, round) and replays verbatim.
    """
    if count < 1:
        raise ValueError("count must be >= 1")
    words = [int(k) & 0xFFFFFFFFFFFFFFFF for k in key]
    # Philox takes exactly two 64-bit key words; fold the domain tag
    # ("SP", sample plane) into the first so other users of the same seed
    # space draw from a disjoint stream
    words[0] ^= 0x5350 << 40
    gen = np.random.Generator(
        np.random.Philox(key=np.asarray(words[:2], np.uint64)))
    return float(gen.random(count)[-1])


def systematic_sample(weights: np.ndarray, n_sample: int,
                      u: float) -> Tuple[np.ndarray, np.ndarray]:
    """Without-replacement PPS sample of ``n_sample`` of ``m`` units.

    Systematic (Madow) selection driven by the single uniform ``u``, with
    iterative certainty-unit extraction so inclusion probabilities are
    *exactly* ``π_i = min(1, s·p_i)`` — which is what makes the HT
    estimator in `ht_interval` unbiased.

    Returns (positions, pis): selected unit indices in ascending order and
    their inclusion probabilities.
    """
    w = np.asarray(weights, np.float64)
    if np.any(w < 0) or not np.all(np.isfinite(w)):
        raise ValueError("weights must be finite and non-negative")
    m = int(w.shape[0])
    s = int(min(n_sample, m))
    if s <= 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    if s >= m:
        return np.arange(m, dtype=np.int64), np.ones(m, np.float64)
    w = np.maximum(w, 1e-12)          # every unit must be reachable

    certain = np.zeros(m, bool)
    while True:                       # extract units with s_r·p_i ≥ 1
        s_r = s - int(certain.sum())
        if s_r <= 0:
            break
        rest = ~certain
        p = s_r * w / max(w[rest].sum(), 1e-300)
        newly = rest & (p >= 1.0)
        if not newly.any():
            break
        certain |= newly

    pis = np.zeros(m, np.float64)
    pis[certain] = 1.0
    selected = certain.copy()
    rest_idx = np.flatnonzero(~certain)
    s_r = s - int(certain.sum())
    if s_r > 0:
        p = s_r * w[rest_idx] / w[rest_idx].sum()     # all < 1 by the loop
        pis[rest_idx] = p
        cum = np.cumsum(p)
        cum[-1] = float(s_r)                          # fp guard
        picks = np.searchsorted(cum, u + np.arange(s_r), side="right")
        picks = np.unique(np.clip(picks, 0, rest_idx.size - 1))
        selected[rest_idx[picks]] = True
    positions = np.flatnonzero(selected).astype(np.int64)
    return positions, pis[positions]


def inclusion_probs(weights: np.ndarray, n_sample: int) -> np.ndarray:
    """Full inclusion-probability vector of `systematic_sample`'s design.

    Systematic PPS inclusion probabilities are a pure function of
    (weights, n_sample) — the uniform only picks *which* units land in the
    sample, not how likely each was.  Mirrors `systematic_sample`'s
    certainty-extraction loop exactly, so
    ``inclusion_probs(w, s)[positions] == pis`` for any draw.  The
    adaptive sampler needs the probabilities of the *undrawn* units too:
    conditional PPS composes round-r draw probabilities onto the
    cumulative inclusion probability of every still-undrawn unit.
    """
    w = np.asarray(weights, np.float64)
    if np.any(w < 0) or not np.all(np.isfinite(w)):
        raise ValueError("weights must be finite and non-negative")
    m = int(w.shape[0])
    s = int(min(n_sample, m))
    if s <= 0:
        return np.zeros(m, np.float64)
    if s >= m:
        return np.ones(m, np.float64)
    w = np.maximum(w, 1e-12)

    certain = np.zeros(m, bool)
    while True:
        s_r = s - int(certain.sum())
        if s_r <= 0:
            break
        rest = ~certain
        p = s_r * w / max(w[rest].sum(), 1e-300)
        newly = rest & (p >= 1.0)
        if not newly.any():
            break
        certain |= newly

    pis = np.zeros(m, np.float64)
    pis[certain] = 1.0
    rest_idx = np.flatnonzero(~certain)
    s_r = s - int(certain.sum())
    if s_r > 0:
        pis[rest_idx] = s_r * w[rest_idx] / w[rest_idx].sum()
    return pis


def ht_estimate(ys: np.ndarray, pis: np.ndarray) -> float:
    """Horvitz–Thompson total: Σ y_i / π_i over the sampled units."""
    ys = np.asarray(ys, np.float64)
    pis = np.asarray(pis, np.float64)
    return float(np.sum(ys / np.maximum(pis, 1e-300)))


def ht_interval(ys: np.ndarray, pis: np.ndarray, n_total: int,
                confidence: float) -> Tuple[float, float, float]:
    """(estimate, lo, hi): HT total plus a small-sample-hardened CI.

    Certainty units (π = 1) contribute exactly; the variance comes from
    the non-certainty draws via the PPS-with-replacement approximation
    — ``Var ≈ Var(t_i) / s_r`` with ``t_i = y_i / p_i`` — which
    needs ≥ 2 such draws; with fewer the interval is (−∞, +∞), which the
    escalation rule reads as "cannot prune, go exact".

    Two deliberate asymmetries harden the *upper* bound — the one the
    escalation rule prunes on, where an optimistic error loses a frequent
    pattern instead of wasting a block:

      * the normal quantile is inflated toward Student's t with
        ``s_r − 1`` degrees of freedom (Cornish–Fisher one-term
        expansion) — at 4 draws the nominal-95% z of 1.96 is closer to 3;
      * ``hi`` additionally carries the largest observed single-unit HT
        contribution ``max y_i/π_i`` — "one more block as heavy as the
        heaviest seen" — so a support concentrated in few blocks cannot
        be pruned off one lucky-low draw;
      * a pattern with **zero observed mass** gets the hidden-block bound
        instead of the (degenerate, zero-width) normal CI: if ``h`` blocks
        each carried ≥ 1 embedding, a coverage-``f`` draw misses all of
        them with probability ≲ ``(1−f)^h``, so at confidence ``1−α`` the
        support may still be as large as ``ln α / ln(1−f)`` — e.g. ≈ 10 at
        f = 0.25, ≈ 4 at f = 0.5.  Zero-mass patterns therefore only prune
        against a τ above that bound, which is exactly the regime (real σ,
        deep levels) where the sampled plane earns its keep.

    ``lo`` is clipped at 0 (supports are non-negative).
    """
    ys = np.asarray(ys, np.float64)
    pis = np.asarray(pis, np.float64)
    est = ht_estimate(ys, pis)
    rest = pis < _CERTAIN
    s_r = int(rest.sum())
    if s_r < 2:
        if s_r == 0:                    # full coverage — exact
            return est, est, est
        return est, -math.inf, math.inf
    f_cov = ys.shape[0] / max(n_total, 1)
    if not np.any(ys > 0):
        hidden = math.log(max(1.0 - confidence, 1e-300)) \
            / math.log(max(1.0 - f_cov, 1e-300))
        return 0.0, 0.0, hidden
    t = ys[rest] * s_r / pis[rest]      # y_i / p_i  (π_i = s_r · p_i)
    # deliberately NO finite-population correction: the with-replacement
    # variance over-covers at high fractions, and over-coverage only costs
    # an escalation (exact, cheap) where under-coverage costs correctness
    var = float(np.var(t, ddof=1)) / s_r
    z = normal_quantile(0.5 + confidence / 2.0)
    z += (z ** 3 + z) / (4.0 * (s_r - 1))          # ≈ t-quantile, df = s_r−1
    half = z * math.sqrt(max(var, 0.0))
    guard = float(np.max(ys[rest] / np.maximum(pis[rest], 1e-300)))
    return est, max(0.0, est - half), est + half + guard


# ---------------------------------------------------------------------------
# sample pass (one same-k group over the sampled schedule)
# ---------------------------------------------------------------------------

def sample_group(
    dev_g: DeviceGraph,
    plans: List,
    group_taus: Sequence[int],
    metric: str,
    cfg: MatchConfig,
    *,
    n: int,
    sampled_ids: np.ndarray,
    deadline: Optional[float] = None,
    schedule_positions: Optional[np.ndarray] = None,
    record_embeddings: bool = False,
):
    """Complete-mode `_mine_group` over the sampled blocks only.

    Returns (ys, outs, dispatches, block_peaks, timed_out, replay) where
    ``ys`` is the (P₀, s) matrix of per-sampled-block support increments —
    the HT estimator's input.  Increments are non-negative for every
    batchable metric (mis/mis_luby counters, MNI minima and fractional
    mass are all monotone non-decreasing in blocks processed).

    With ``record_embeddings=True`` the steps run in capture mode and
    ``replay`` holds, per pattern, {schedule position (str) →
    {"emb" (the block's raw `match_block` rows), "found", "ovf", "peak"}}
    — JSON-native, rides in the `SampledCursor`, and lets exact escalation
    *replay* these blocks instead of re-matching them
    (``schedule_positions`` maps the subset loop index back to the level
    schedule).
    """
    hist: List[np.ndarray] = []

    def on_block(gs):
        hist.append(np.asarray(gs.supports, np.int64).copy())

    emb_sink = None
    replay: Optional[List[Dict[str, Any]]] = None
    if record_embeddings:
        assert schedule_positions is not None
        spos = np.asarray(schedule_positions, np.int64)
        replay = [dict() for _ in plans]

        def emb_sink(b, emb, nv, found, ovf, peak, bucket_map):
            pos = str(int(spos[b]))
            for row in range(int(bucket_map.size)):
                gi = int(bucket_map[row])
                if gi < 0:
                    continue
                c = int(nv[row])
                replay[gi][pos] = {
                    "emb": emb[row, :c].tolist(),
                    "found": int(found[row]),
                    "ovf": bool(ovf[row]),
                    "peak": int(peak[row]),
                }

    outs, timed_out, dispatches, bpeaks, _ = _mine_group(
        dev_g, plans, list(group_taus), metric, cfg, complete=True, n=n,
        deadline=deadline, on_block=on_block, block_order=sampled_ids,
        emb_sink=emb_sink)
    if timed_out:
        return None, outs, dispatches, bpeaks, True, None
    finals = np.asarray([o.support for o in outs], np.int64)
    cum = (np.stack(hist + [finals], axis=1) if hist
           else finals[:, None])                       # (P₀, s) cumulative
    ys = np.diff(cum, axis=1, prepend=0)               # per-block increments
    return ys, outs, dispatches, bpeaks, False, replay


# ---------------------------------------------------------------------------
# hooks adapter: escalation groups live in the level recorder's normal
# group surface, but index the escalated *subset* — translate both ways
# ---------------------------------------------------------------------------

class _EscalationHooks:
    def __init__(self, hooks, esc_idx: List[int]):
        self._h = hooks
        self._to_level = list(esc_idx)
        self._to_local = {i: j for j, i in enumerate(esc_idx)}

    def resume_outcomes(self):
        ro = self._h.resume_outcomes()
        if not ro:
            return None
        return {self._to_local[i]: o for i, o in ro.items()
                if i in self._to_local}

    def resume_dispatches(self) -> int:
        return self._h.resume_dispatches()

    def resume_block_peaks(self):
        fn = getattr(self._h, "resume_block_peaks", None)
        return fn() if fn is not None else None

    def group_resume(self, k: int, lo: int):
        return self._h.group_resume(k, lo)

    def on_group_state(self, k: int, lo: int, state) -> None:
        self._h.on_group_state(k, lo, state)

    def resume_replans(self) -> int:
        fn = getattr(self._h, "resume_replans", None)
        return fn() if fn is not None else 0

    def on_group_done(self, k, lo, idxs, outcomes, dispatches,
                      block_peaks=None, replans=0) -> None:
        self._h.on_group_done(k, lo, [self._to_level[i] for i in idxs],
                              outcomes, dispatches, block_peaks=block_peaks,
                              replans=replans)


# ---------------------------------------------------------------------------
# level executor
# ---------------------------------------------------------------------------

def _estimated_outcome(est: float, tau: int, out: PatternOutcome, s: int,
                       *, pruned: bool) -> PatternOutcome:
    """An ``estimated=True`` outcome from the sample pass.

    ``pruned=True`` (escalation enabled, interval below τ): infrequent by
    contract, support clamped to τ−1 so the flag and the value agree.
    ``pruned=False`` (escalation disabled): the raw floor estimate decides
    frequency.  ``embeddings_found``/``max_count`` are the *sampled*
    observations, not extrapolations — documented in docs/architecture.md.
    """
    sup = int(math.floor(est))
    if pruned:
        sup = max(0, min(sup, tau - 1))
    return PatternOutcome(
        support=sup, frequent=bool(sup >= tau),
        embeddings_found=out.embeddings_found, overflowed=out.overflowed,
        blocks_run=s, max_count=out.max_count, estimated=True)


def _outcome_dict(o: PatternOutcome) -> Dict[str, Any]:
    return {
        "support": int(o.support), "frequent": bool(o.frequent),
        "embeddings_found": int(o.embeddings_found),
        "overflowed": bool(o.overflowed), "blocks_run": int(o.blocks_run),
        "max_count": int(o.max_count), "estimated": bool(o.estimated),
    }


def evaluate_level_sampled(
    host_g: DataGraph,
    dev_g: DeviceGraph,
    patterns: Sequence[Pattern],
    taus: Sequence[int],
    metric: str,
    cfg: MatchConfig,
    *,
    sample: Optional[Dict[str, Any]],
    confidence: float = 0.95,
    escalate: bool = True,
    complete: bool = False,
    deadline: Optional[float] = None,
    max_batch: int = DEFAULT_MAX_BATCH,
    hooks=None,
    block_order: Optional[np.ndarray] = None,
    sample_rounds: int = 1,
    counters: Optional[Dict[str, int]] = None,
) -> Tuple[List[Optional[PatternOutcome]], bool, LevelTelemetry]:
    """Evaluate a candidate level with the sampled plane (module docstring).

    ``sample`` is the planner's recorded draw (`LevelPlan.sample`):
    ``{"positions", "pis", "key", "w", ...}`` with positions indexing the
    schedule ``block_order``.  ``None`` — or full coverage, or
    ``complete=True`` — degenerates to the exact batched plane.

    **Adaptive rounds** (``sample_rounds`` > 1): after classifying the
    plan's round-0 draw, still-undecided patterns get further geometric
    rounds — each doubles coverage by drawing ``min(|undrawn|, |drawn|)``
    new blocks from the complement via conditional PPS.  A drawn unit's
    estimator π is *frozen* at its cumulative inclusion probability at
    draw time (round r composes ``π' = π + (1−π)·q_r`` onto every
    complement unit); freezing understates the true multi-round inclusion,
    so the HT total only over-estimates — escalating more, never pruning a
    frequent pattern.  Rounds stop when the undecided set stops shrinking,
    empties, coverage completes, or ``sample_rounds`` is reached.  Round
    draws are pure functions of (key, round, weights, drawn-set) and each
    round is recorded in the phase cursor, so killed sessions resume
    mid-round bit-identically.

    **Escalation reuse** (``escalate=True``): the sample pass runs in
    capture mode, recording each (pattern, block) raw match result; the
    exact escalation then walks the full schedule but *replays* sampled
    positions with the cheap update-only step instead of re-matching them
    (`evaluate_level_batched`'s ``replay``).  ``counters`` threads through
    to the escalation pass only — ``{"match_blocks", "replay_blocks"}``
    counts prove no sampled block is ever re-matched.

    ``hooks`` extends the batched resume surface with the sampled-phase
    cursor: ``resume_sampled()`` → the recorded phase dict or None, and
    ``on_sampled(dict)`` — called after every completed sample group and
    once more when classification lands, each a snapshot point.  The
    escalation phase reuses the *group* surface (``group_resume`` /
    ``on_group_state`` / ``on_group_done``) verbatim, with outcome indices
    mapped back to level positions.
    """
    assert len(patterns) == len(taus)
    n = host_g.n
    total_blocks = -(-n // cfg.root_block)
    if block_order is None:
        block_order = np.arange(total_blocks, dtype=np.int64)
    m = int(block_order.shape[0])

    if sample is None:
        positions = np.arange(m, dtype=np.int64)
        pis = np.ones(m, np.float64)
    else:
        positions = np.asarray(sample["positions"], np.int64)
        pis = np.asarray(sample["pis"], np.float64)
    s = int(positions.shape[0])

    if complete or s >= m:
        # full coverage: the exact batched plane IS the sampled plane at
        # fraction 1.0 — real τ early exit, zero escalations
        outcomes, timed_out, tel = evaluate_level_batched(
            host_g, dev_g, patterns, taus, metric, cfg, complete=complete,
            deadline=deadline, max_batch=max_batch, hooks=hooks,
            block_order=block_order)
        tel.sampled = {
            "fraction": 1.0, "n_sample": m, "n_blocks": m, "rounds": 0,
            "escalated": 0, "pruned": 0, "exact": True,
            "confidence": float(confidence), "ci_width_mean": 0.0,
        }
        return outcomes, timed_out, tel

    P = len(patterns)
    w = np.maximum(np.asarray(sample.get("w", np.ones(m)), np.float64),
                   1e-12)
    key = list(sample.get("key", []))
    telemetry = LevelTelemetry()
    peaks = np.zeros(total_blocks, np.int64)
    outcomes: List[Optional[PatternOutcome]] = [None] * P

    rec = None
    if hooks is not None:
        fn = getattr(hooks, "resume_sampled", None)
        rec = fn() if fn is not None else None
    sgroups: Dict[str, Dict[str, Any]] = dict(rec["groups"]) if rec else {}
    classify: Optional[Dict[str, Any]] = rec.get("classify") if rec else None
    rec_rounds: List[Dict[str, Any]] = list((rec or {}).get("rounds") or [])
    rounds: List[Dict[str, Any]] = []

    def record(phase: str) -> None:
        if hooks is None:
            return
        fn = getattr(hooks, "on_sampled", None)
        if fn is not None:
            fn({"phase": phase, "positions": [int(p) for p in positions],
                "key": key, "rounds": rounds, "groups": sgroups,
                "classify": classify})

    # cumulative inclusion state after the plan's round-0 draw.  The
    # frozen per-unit π of round 0 are the plan's exact `pis`;
    # `inclusion_probs` gives the matching full-schedule vector (the
    # requested draw size, not the post-clip count, parameterises the
    # design — `n_requested`).
    drawn = np.zeros(m, bool)
    drawn[positions] = True
    pi_cum = inclusion_probs(w, int(sample.get("n_requested", s)))

    ys_acc: Dict[int, List[float]] = {i: [] for i in range(P)}
    pis_acc: Dict[int, List[float]] = {i: [] for i in range(P)}
    outs_acc: Dict[int, Dict[str, Any]] = {}
    replay_tab: Dict[int, Dict[int, Any]] = {i: {} for i in range(P)}
    width_of: Dict[int, float] = {}
    pruned: Dict[str, Dict[str, Any]] = {}
    undecided: List[int] = list(range(P))
    max_rounds = max(1, int(sample_rounds))
    n_rounds_run = 0
    timed_out = False

    # -- phases 1+2: sample rounds + classification -------------------------
    if classify is not None:
        # resumed past classification: rebuild the drawn set and the
        # escalation replay table from the recorded rounds/groups
        rounds = rec_rounds
        n_rounds_run = int(classify.get("rounds", 1 + len(rec_rounds)))
        for rr in rec_rounds:
            drawn[np.asarray(rr["positions"], np.int64)] = True
        for g in sgroups.values():
            rep = g.get("replay")
            if rep is not None:
                for j, i in enumerate(g["idxs"]):
                    replay_tab[int(i)].update(
                        {int(p): v for p, v in rep[j].items()})
    else:
        r = 0
        while True:
            # this round's draw: plan (r = 0), recorded (resume), or live
            if r == 0:
                r_pos, r_pis = positions, pis
            elif r <= len(rec_rounds):
                rr = rec_rounds[r - 1]
                comp = np.flatnonzero(~drawn)
                r_pos = np.asarray(rr["positions"], np.int64)
                r_pis = np.asarray(rr["pis"], np.float64)
                pi_cum[comp] += (1.0 - pi_cum[comp]) \
                    * inclusion_probs(w[comp], int(rr["n_new"]))
                drawn[r_pos] = True
                rounds.append(dict(rr))
            else:
                comp = np.flatnonzero(~drawn)
                n_new = int(min(comp.size, drawn.sum()))
                if n_new <= 0:
                    break
                u_r = sample_uniform(key, count=r + 1)
                pos_local, pis_local = systematic_sample(w[comp], n_new, u_r)
                r_pos = comp[pos_local]
                # freeze the estimator π at draw time: composed cumulative
                # inclusion, conditional on not being drawn earlier
                r_pis = pi_cum[r_pos] + (1.0 - pi_cum[r_pos]) * pis_local
                pi_cum[comp] += (1.0 - pi_cum[comp]) \
                    * inclusion_probs(w[comp], n_new)
                drawn[r_pos] = True
                rounds.append({
                    "round": int(r), "n_new": int(n_new),
                    "positions": [int(x) for x in r_pos],
                    "pis": [float(x) for x in r_pis],
                })

            # run the round over the still-undecided patterns
            und = sorted(undecided)
            sub_groups = list(level_groups([patterns[i] for i in und],
                                           max_batch))
            sampled_ids_r = block_order[r_pos]
            for k, lo, jdxs in sub_groups:
                idxs = [und[j] for j in jdxs]
                gk = f"{k}:{lo}:r{r}"
                if gk in sgroups:
                    continue
                if deadline is not None and time.monotonic() > deadline:
                    timed_out = True
                    break
                plans = [make_plan(patterns[i], host_g) for i in idxs]
                ys, outs, disp, bpeaks, g_timed, rep = sample_group(
                    dev_g, plans, [taus[i] for i in idxs], metric, cfg, n=n,
                    sampled_ids=sampled_ids_r, deadline=deadline,
                    schedule_positions=r_pos, record_embeddings=escalate)
                if g_timed:
                    timed_out = True
                    break
                sgroups[gk] = {
                    "idxs": [int(i) for i in idxs],
                    "ys": ys.tolist(),
                    "outs": [_outcome_dict(o) for o in outs],
                    "dispatches": int(disp),
                    "block_peaks": [int(x) for x in bpeaks],
                    **({"replay": rep} if rep is not None else {}),
                }
                record("sample")
            if timed_out:
                break

            # merge the round into the per-pattern accumulators
            for k, lo, jdxs in sub_groups:
                g = sgroups[f"{k}:{lo}:r{r}"]
                ys_g = np.asarray(g["ys"], np.float64)
                rep = g.get("replay")
                for j, i in enumerate(g["idxs"]):
                    i = int(i)
                    ys_acc[i].extend(float(x) for x in ys_g[j])
                    pis_acc[i].extend(float(x) for x in r_pis)
                    od = dict(g["outs"][j])
                    prev_od = outs_acc.get(i)
                    if prev_od is not None:
                        od["embeddings_found"] += prev_od["embeddings_found"]
                        od["overflowed"] = (od["overflowed"]
                                            or prev_od["overflowed"])
                        od["max_count"] = max(od["max_count"],
                                              prev_od["max_count"])
                    outs_acc[i] = od
                    if rep is not None:
                        replay_tab[i].update(
                            {int(p): v for p, v in rep[j].items()})

            # classify: prune what the cumulative interval settles
            newly_pruned = 0
            still: List[int] = []
            for i in und:
                ys_i = np.asarray(ys_acc[i], np.float64)
                pis_i = np.asarray(pis_acc[i], np.float64)
                est, lo_ci, hi_ci = ht_interval(ys_i, pis_i, m, confidence)
                out = PatternOutcome(**outs_acc[i])
                s_i = int(ys_i.shape[0])
                if not escalate:
                    pruned[str(i)] = _outcome_dict(_estimated_outcome(
                        est, taus[i], out, s_i, pruned=False))
                elif hi_ci < taus[i]:
                    pruned[str(i)] = _outcome_dict(_estimated_outcome(
                        est, taus[i], out, s_i, pruned=True))
                else:
                    still.append(i)
                    continue
                if math.isfinite(hi_ci - lo_ci):
                    width_of[i] = float(hi_ci - lo_ci)
                newly_pruned += 1
            undecided = still
            n_rounds_run = r + 1
            if (not undecided or not escalate or newly_pruned == 0
                    or bool(drawn.all()) or n_rounds_run >= max_rounds):
                break
            r += 1

        if not timed_out:
            classify = {
                "escalate": [int(i) for i in undecided], "pruned": pruned,
                "rounds": int(n_rounds_run),
                # satellite fix: the settled-set mean is None — not NaN,
                # not 0.0 — when every pattern escalated
                "ci_width_mean": (float(np.mean(list(width_of.values())))
                                  if width_of else None),
            }
            record("escalate")

    telemetry.dispatches += sum(g["dispatches"] for g in sgroups.values())
    for gk, g in sgroups.items():
        peaks = np.maximum(peaks, np.asarray(g["block_peaks"], np.int64))
        telemetry.state_bytes = max(
            telemetry.state_bytes,
            _bucket_size(len(g["idxs"]))
            * (_state_bytes(metric, int(gk.split(":")[0]), n)
               + transient_match_bytes(cfg, int(gk.split(":")[0]))))
    if timed_out:
        telemetry.block_peaks = peaks
        return outcomes, True, telemetry

    esc_idx = [int(i) for i in classify["escalate"]]
    for i_str, od in classify["pruned"].items():
        outcomes[int(i_str)] = PatternOutcome(**od)

    # -- phase 3: exact escalation (replaying every sampled block) ----------
    if esc_idx:
        adapter = _EscalationHooks(hooks, esc_idx) if hooks is not None \
            else None
        replay_list = None
        if all(replay_tab.get(i) for i in esc_idx):
            replay_list = [{int(p): v for p, v in replay_tab[i].items()}
                           for i in esc_idx]
        outs2, esc_timed, tel2 = evaluate_level_batched(
            host_g, dev_g, [patterns[i] for i in esc_idx],
            [taus[i] for i in esc_idx], metric, cfg, complete=complete,
            deadline=deadline, max_batch=max_batch, hooks=adapter,
            block_order=block_order, replay=replay_list, counters=counters)
        timed_out |= esc_timed
        for i, o in zip(esc_idx, outs2):
            outcomes[i] = o
        telemetry.dispatches += tel2.dispatches
        telemetry.state_bytes = max(telemetry.state_bytes, tel2.state_bytes)
        if tel2.block_peaks is not None:
            peaks = np.maximum(peaks, tel2.block_peaks)

    telemetry.block_peaks = peaks
    for o in outcomes:
        if o is not None:
            telemetry.max_count = max(telemetry.max_count, o.max_count)
            telemetry.overflowed |= o.overflowed
    drawn_total = int(drawn.sum())
    cwm = classify["ci_width_mean"]
    telemetry.sampled = {
        "fraction": drawn_total / m, "n_sample": drawn_total, "n_blocks": m,
        "rounds": int(classify.get("rounds", n_rounds_run)),
        "escalated": len(esc_idx), "pruned": len(classify["pruned"]),
        "exact": False, "confidence": float(confidence),
        "ci_width_mean": None if cwm is None else float(cwm),
    }
    assert timed_out or all(o is not None for o in outcomes)
    return outcomes, timed_out, telemetry
