"""Adaptive execution planner — cost-model-driven level scheduling.

Every mining level asks the same three questions:

  1. **Which data plane?**  The batched plane (`core/batched.py`) amortizes
     dispatch + host-sync overhead across a level's candidates and wins
     ≥2–4× when levels are dispatch-bound; but when a single pattern's
     block already saturates the device (one pattern, a big ``cap·chunk``
     grid) it is parity-or-slower than the sequential oracle.  The
     distributed plane adds the mesh, at collective-latency cost.
  2. **How wide a pattern bucket?**  Bigger buckets amortize more dispatch
     overhead but multiply transient device memory.
  3. **What matcher geometry?**  `MatchConfig.for_graph` is one
     graph-global guess; actual frontier occupancy is a per-level quantity
     the previous level already measured (``max_count`` telemetry), so
     ``cap`` can be right-sized level by level — on compute-bound levels
     that is directly proportional compute saved.

`ExecutionPlanner` answers all three from a small calibrated cost model
(`CostModel`: dispatch overhead + per-lane throughput + vmap fusion-loss
factor — fitted by ``benchmarks/calibrate.py``, loaded from a JSON file
with safe built-in defaults) plus the level's observable inputs: candidate
count, per-pattern frontier occupancy of the previous level, and graph
degree stats.  With ``MiningConfig.execution == "auto"`` (the default)
`mine()` consults the planner at every level boundary and records the
decision in ``MiningResult.per_level[level]["plan"]`` and in the session
snapshot, so a ``--resume`` replays the in-flight level's plan
bit-identically even if the calibration file changed between processes.

Result-preservation contract (what "auto is bit-identical to every forced
plane" rests on):

  * plane choice never changes per-pattern results — that is the batched ≡
    sequential equivalence contract, property-tested since PR 1;
  * ``cap`` right-sizing preserves results whenever no level overflows the
    derived cap (truncation is the *only* cap-dependent behaviour, and it
    is always flagged via ``overflowed``); the planner therefore only
    shrinks with ≥``CAP_HEADROOM``× headroom over the observed peak, never
    below ``CAP_FLOOR``, and not at all when the previous level overflowed;
  * ``chunk``/``max_chunks`` are **never** changed when ``max_chunks > 1``:
    survivors are packed in (chunk, row, position) order, so re-chunking a
    multi-chunk gather would permute embedding priority and with it the
    greedy-mIS selection.  When one chunk already covers the max degree the
    order is plain row-major and shrinking ``chunk`` is order-preserving;
  * ``two_phase`` toggling preserves results absent overflow (same
    survivors, same packing order — `tests/kernels` pin this).

**Degree-ordered root blocks** (`root_block_order`): root blocks are
dispatched in descending max-out-degree order instead of vertex-id order.
High-yield roots are matched first, so the τ early-exit in ``mis`` /
``mis_luby`` fires after fewer blocks.  The permutation is a static
function of (graph, root_block, ``MiningConfig.root_order``): it is the
*schedule*, shared verbatim by all three planes (which keeps them
bit-identical to each other) and part of the session config fingerprint
(which keeps resumes bit-identical).  Completed metric values remain
deterministic because mIS priority is embedding-row order *within* the
chosen schedule.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batched import _bucket_size as _pow2_ceil
from .graph import DataGraph
from .matcher import MatchConfig

__all__ = [
    "CostModel", "LevelPlan", "ExecutionPlanner", "block_degree_stat",
    "root_block_order", "DEFAULT_CALIBRATION_FILE", "load_calibration",
    "persist_escalation_fraction",
]

# calibration file the planner looks for (cwd-relative; override with the
# REPRO_PLANNER_CALIBRATION env var).  Written by `benchmarks/calibrate.py`.
DEFAULT_CALIBRATION_FILE = "planner_calibration.json"
CALIBRATION_ENV = "REPRO_PLANNER_CALIBRATION"
# schema 2 added per-metric row times (row_time_{mni,frac,luby}_s); schema 3
# added the measured escalation fraction (escalation_fraction — the sampled
# plane's pricing warm-start).  Schema-1/2 files still load — the missing
# constants fall back to the shared one / the ESCALATION_PRIOR constant.
CALIBRATION_SCHEMA = 3
CALIBRATION_SCHEMAS = (1, 2, 3)

# cap right-sizing safety rails (see module docstring / docs/architecture.md)
CAP_HEADROOM = 4        # derived cap ≥ headroom × observed peak occupancy
CAP_FLOOR = 1024        # never shrink below this many frontier rows

# sampled plane (execution="sampled"): prior on the fraction of a level's
# batched cost the exact escalation pass re-spends, scaled by the unsampled
# fraction — the cost-model row for the sample pass prices
#   f·batched + ESCALATION_PRIOR·(1−f)·batched
# so fraction 1.0 prices exactly like (and degenerates to) forced batched
ESCALATION_PRIOR = 0.25
# below this many root blocks a sample cannot both draw ≥1 block and leave
# ≥1 out — the plan falls back to the exact batched plane
MIN_SAMPLED_BLOCKS = 2
# auto only picks the sampled plane when its priced cost undercuts the
# batched row by this factor — a win margin that absorbs the model's own
# error (escalation prediction, replay pricing) before auto gambles on a
# statistical plane whose worst case is "everything escalates"
SAMPLED_MARGIN = 0.9


def hidden_mass_bound(confidence: float, f_cov: float) -> float:
    """Max support the unsampled blocks can hide at the CI confidence.

    Mirrors `sampled.ht_interval`'s zero-mass hidden-block bound: with
    covered probability mass ``f_cov``, a pattern whose sample saw nothing
    can still hold up to ``ln(1−confidence)/ln(1−f_cov)`` embeddings before
    the miss probability drops below ``1−confidence``.  The planner uses it
    as an eligibility gate: when a level's smallest τ is below this bound,
    even zero-mass (i.e. hopeless) patterns escalate and the sample pass is
    pure overhead.
    """
    if f_cov >= 1.0:
        return 0.0
    alpha = max(1e-12, 1.0 - confidence)
    return math.log(alpha) / math.log(max(1e-300, 1.0 - f_cov))


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostModel:
    """Three-term linear device-step model, plus the vmap fusion tax.

    One batched step over a bucket of P same-k patterns costs

        dispatch_overhead_s
          + P · (lanes(cfg, k) · lane_time_s + cap · row_time_s)
              · (vmap_factor if P > 1 else 1)

    with two distinct work terms — they scale differently and conflating
    them is exactly the miscalibration that flips plane decisions:

      * ``lanes · lane_time_s`` — the expansion grid:
        ``(k−1) · cap · chunk · max_chunks`` candidate lanes, each paying
        the gather/mask/compact pipeline;
      * ``cap · row_time_s`` — the per-frontier-row metric update (the
        greedy-mIS ``lax.scan`` walks every row of the frontier table;
        dominant on CPU where scan iteration overhead is large).

    ``dispatch_overhead_s`` is everything a step pays regardless of
    geometry: program dispatch, host↔device sync, the host loop's python
    bookkeeping.  ``vmap_factor ≥ 1`` is the measured per-lane slowdown
    of the vmapped program vs the unbatched one (XLA loses cross-op
    fusion on wide batched grids; see docs/architecture.md "Why the
    vmapped matcher loses fusion").  The sequential plane pays the
    overhead once per pattern per block with no vmap tax.

    ``row_time_s`` is fitted on the ``mis`` step; the metric scan term is
    the one constant that genuinely differs across metrics (greedy mIS's
    ``lax.scan`` vs MNI's scatter-OR vs frac's scatter-add), so schema-2
    calibrations carry optional per-metric overrides
    (``row_time_{mni,frac,luby}_s`` — ``row_time(metric)`` resolves them,
    falling back to the shared constant for schema-1 files and defaults).
    Everything else is metric-independent: the model prices *relative*
    plane/bucket choices, not absolute runtimes.  Defaults are
    conservative CPU numbers.
    """

    dispatch_overhead_s: float = 2.0e-3
    lane_time_s: float = 2.0e-9
    row_time_s: float = 4.0e-6
    vmap_factor: float = 1.15
    row_time_mni_s: Optional[float] = None
    row_time_frac_s: Optional[float] = None
    row_time_luby_s: Optional[float] = None
    # schema 3: measured per-run escalation fraction of the sampled plane
    # (escalated / classified, persisted by `launch/mine.py` after a
    # sampled run) — warm-starts the auto pricing's escalation predictor
    # when a level has no telemetry of its own yet.  None (schema-1/2
    # files, fresh fits) falls back to the ESCALATION_PRIOR constant.
    escalation_fraction: Optional[float] = None
    source: str = "defaults"

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": CALIBRATION_SCHEMA,
            "dispatch_overhead_s": self.dispatch_overhead_s,
            "lane_time_s": self.lane_time_s,
            "row_time_s": self.row_time_s,
            "vmap_factor": self.vmap_factor,
            "row_time_mni_s": self.row_time_mni_s,
            "row_time_frac_s": self.row_time_frac_s,
            "row_time_luby_s": self.row_time_luby_s,
            "escalation_fraction": self.escalation_fraction,
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CostModel":
        base = cls()

        def opt(key: str) -> Optional[float]:
            v = d.get(key)
            return None if v is None else float(v)

        try:
            return cls(
                dispatch_overhead_s=float(
                    d.get("dispatch_overhead_s", base.dispatch_overhead_s)),
                lane_time_s=float(d.get("lane_time_s", base.lane_time_s)),
                row_time_s=float(d.get("row_time_s", base.row_time_s)),
                vmap_factor=max(1.0, float(d.get("vmap_factor",
                                                 base.vmap_factor))),
                row_time_mni_s=opt("row_time_mni_s"),
                row_time_frac_s=opt("row_time_frac_s"),
                row_time_luby_s=opt("row_time_luby_s"),
                escalation_fraction=opt("escalation_fraction"),
                source=str(d.get("source", "file")),
            )
        except (TypeError, ValueError):
            return base

    def lanes(self, cfg: MatchConfig, k: int) -> int:
        return max(1, (k - 1)) * cfg.cap * cfg.chunk * cfg.max_chunks

    def row_time(self, metric: str = "mis") -> float:
        """The metric-scan constant for ``metric`` (schema-2 override or
        the shared mis-fitted ``row_time_s``)."""
        override = {"mni": self.row_time_mni_s,
                    "frac": self.row_time_frac_s,
                    "mis_luby": self.row_time_luby_s}.get(metric)
        return self.row_time_s if override is None else override

    def pattern_work_s(self, cfg: MatchConfig, k: int,
                       metric: str = "mis") -> float:
        """Device work of ONE pattern's block step (no overhead/tax)."""
        return (self.lanes(cfg, k) * self.lane_time_s
                + cfg.cap * self.row_time(metric))

    def block_step_s(self, cfg: MatchConfig, k: int, bucket: int,
                     *, batched: bool, metric: str = "mis") -> float:
        """Predicted wall time of ONE device step over one root block."""
        factor = self.vmap_factor if (batched and bucket > 1) else 1.0
        return (self.dispatch_overhead_s
                + bucket * self.pattern_work_s(cfg, k, metric) * factor)

    def esc_prior(self) -> float:
        """Escalation-mass prior: the measured fraction when calibrated
        (schema 3), the ESCALATION_PRIOR constant otherwise — clamped to
        [0, 1] so a corrupt calibration can't price a negative pass."""
        if self.escalation_fraction is None:
            return ESCALATION_PRIOR
        return min(1.0, max(0.0, float(self.escalation_fraction)))

    def replay_step_s(self, cfg: MatchConfig, k: int, bucket: int,
                      *, metric: str = "mis") -> float:
        """Predicted wall time of ONE update-only replay step.

        Escalation reuse replays a sampled block's recorded embeddings
        through the metric update without re-running the expansion grid —
        so the step pays dispatch plus the per-row metric scan, but no
        ``lanes · lane_time`` term.
        """
        factor = self.vmap_factor if bucket > 1 else 1.0
        return (self.dispatch_overhead_s
                + bucket * cfg.cap * self.row_time(metric) * factor)


def load_calibration(path: Optional[str] = None) -> CostModel:
    """Load the fitted `CostModel`, falling back to safe defaults.

    Search order: explicit ``path`` (exclusively, when given) →
    ``$REPRO_PLANNER_CALIBRATION`` → ``./planner_calibration.json``.  A
    missing or malformed file is never an error — the planner must work
    out of the box.
    """
    env = os.environ.get(CALIBRATION_ENV)
    candidates = [path] if path is not None else [env,
                                                  DEFAULT_CALIBRATION_FILE]
    # the cwd default may legitimately be absent; an *explicitly requested*
    # file (argument or env var) that can't be used deserves a warning —
    # silently planning with different constants than asked for is worse
    # than noise on stderr
    explicit = {c for c in (path, env) if c}
    for cand in candidates:
        if not cand:
            continue
        problem = None
        p = Path(cand)
        if not p.is_file():
            problem = "not found"
        else:
            try:
                d = json.loads(p.read_text())
            except (OSError, ValueError) as e:
                problem, d = f"unreadable ({e})", None
            if d is not None and d.get("schema") not in CALIBRATION_SCHEMAS:
                problem = (f"schema {d.get('schema')!r} not in "
                           f"{CALIBRATION_SCHEMAS}")
        if problem is not None:
            if cand in explicit:
                # do NOT fall through to whatever file happens to sit in
                # cwd — the user asked for this one specifically
                print(f"[planner] ignoring calibration {cand}: {problem}; "
                      f"using built-in defaults", file=sys.stderr)
                return CostModel()
            continue
        # `source` records provenance-as-loaded: the path wins over any
        # source the file itself carries (calibrate.py writes "fit")
        d["source"] = str(p)
        return CostModel.from_dict(d)
    return CostModel()


def persist_escalation_fraction(fraction: float,
                                path: Optional[str] = None) -> Optional[str]:
    """Fold a run's measured escalation fraction into the calibration file.

    The sampled-plane pricing (`ExecutionPlanner._price_sampled`) falls
    back to ``ESCALATION_PRIOR`` when a level has no telemetry; persisting
    the measured fraction (schema 3) warm-starts the next run's prior from
    real data.  EMA with weight 0.5 against any existing value smooths
    run-to-run noise.  Resolution mirrors `load_calibration` (argument →
    env → cwd default); schema-1/2 files are upgraded in place, other
    existing constants are preserved, and any I/O or parse problem is
    swallowed (calibration is an optimization, never a correctness input).
    Returns the path written, or None.
    """
    frac = min(1.0, max(0.0, float(fraction)))
    target = path or os.environ.get(CALIBRATION_ENV) \
        or DEFAULT_CALIBRATION_FILE
    p = Path(target)
    d: Dict[str, Any] = {}
    if p.is_file():
        try:
            loaded = json.loads(p.read_text())
            if (isinstance(loaded, dict)
                    and loaded.get("schema") in CALIBRATION_SCHEMAS):
                d = loaded
        except (OSError, ValueError):
            pass
    prev = d.get("escalation_fraction")
    if isinstance(prev, (int, float)):
        frac = 0.5 * float(prev) + 0.5 * frac
    d["schema"] = CALIBRATION_SCHEMA
    d["escalation_fraction"] = frac
    try:
        p.write_text(json.dumps(d, indent=2, sort_keys=True) + "\n")
    except OSError:
        return None
    return str(p)


# ---------------------------------------------------------------------------
# root-block schedule
# ---------------------------------------------------------------------------

def block_degree_stat(g: DataGraph, root_block: int) -> np.ndarray:
    """Per-root-block max out-degree (block-id indexed, int64 ≥ −1).

    The yield proxy shared by the degree schedule (`root_block_order`) and
    the sampled plane's fallback draw weights (no occupancy telemetry yet
    at k = 2).
    """
    n_blocks = max(1, -(-g.n // root_block))
    deg = np.diff(g.out_indptr).astype(np.int64)
    padded = np.full(n_blocks * root_block, -1, np.int64)
    padded[: deg.shape[0]] = deg
    return padded.reshape(n_blocks, root_block).max(axis=1)


def root_block_order(g: DataGraph, root_block: int,
                     mode: str = "degree") -> np.ndarray:
    """Static permutation of root-block ids — the level's block schedule.

    ``"degree"``: blocks sorted by descending max out-degree of their
    vertices (stable, so ties keep vertex-id order) — high-yield roots run
    first and τ early-exit terminates levels sooner.  ``"vertex"``: the
    legacy identity order.  The permutation depends only on
    (graph, root_block, mode), so every plane — and every resume — walks
    the identical schedule.
    """
    n_blocks = max(1, -(-g.n // root_block))
    if mode == "vertex" or n_blocks == 1:
        return np.arange(n_blocks, dtype=np.int64)
    if mode != "degree":
        raise ValueError('root_order must be "degree" or "vertex"')
    block_max = block_degree_stat(g, root_block)
    # stable descending sort: ties stay in ascending block-id order
    return np.argsort(-block_max, kind="stable").astype(np.int64)


# ---------------------------------------------------------------------------
# per-level plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One level's execution decision (JSON-stable via to/from_dict)."""

    plane: str                 # "sequential" | "batched" | "distributed"
                               # | "sampled"
    match: MatchConfig         # per-level matcher geometry
    max_batch: int             # pattern-bucket ceiling for level_groups
    # sampled plane only: the level's recorded block draw —
    # {"fraction", "n_sample", "positions" (schedule indices), "pis"
    # (inclusion probabilities), "key" (RNG key words), "weights"
    # ("occupancy" | "degree"), "w" (full schedule-ordered weight vector —
    # what the adaptive rounds redraw from)}.  Part of to_dict/from_dict,
    # so a resumed level replays the *identical* sample instead of
    # re-drawing.
    sample: Optional[Dict[str, Any]] = None
    # auto pricing record: every input of the sampled-vs-batched decision
    # ({"batched_s", "sampled_s", "replay_s", "fraction", "esc",
    # "esc_source", "margin", "tau_min", "hidden_bound", "chosen"}) —
    # recorded whenever auto evaluated the sampled plane, chosen or not,
    # and replayed verbatim on resume (part of to_dict/from_dict).
    pricing: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        """The decision as recorded in per_level / session snapshots.

        JSON-native values only (the sample dict holds ints/floats/
        strings), so the dict survives a JSON round-trip unchanged — which
        is what makes a replayed decision compare equal to the original in
        the resume bit-identity tests.
        """
        m = self.match
        d = {
            "plane": self.plane,
            "cap": int(m.cap),
            "root_block": int(m.root_block),
            "chunk": int(m.chunk),
            "max_chunks": int(m.max_chunks),
            "two_phase": bool(m.two_phase),
            "max_batch": int(self.max_batch),
        }
        if self.sample is not None:
            d["sample"] = self.sample
        if self.pricing is not None:
            d["pricing"] = self.pricing
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any], base: MatchConfig) -> "LevelPlan":
        """Rebuild a recorded decision on top of the run's base geometry."""
        match = dataclasses.replace(
            base,
            cap=int(d["cap"]),
            root_block=int(d["root_block"]),
            chunk=int(d["chunk"]),
            max_chunks=int(d["max_chunks"]),
            two_phase=bool(d["two_phase"]),
        )
        return cls(plane=str(d["plane"]), match=match,
                   max_batch=int(d["max_batch"]), sample=d.get("sample"),
                   pricing=d.get("pricing"))


class ExecutionPlanner:
    """Chooses (plane, bucket, geometry) per level for ``mine()``.

    Forced execution modes pass through unchanged (the three planes stay
    available as oracles); ``"auto"`` applies the cost model.  The planner
    is pure host arithmetic — it never touches the device — and fully
    deterministic given (graph, config, cost model), which the session
    runtime leans on for resume bit-identity (it additionally pins the
    cost model and the in-flight level's decision in every snapshot).
    """

    def __init__(self, g: DataGraph, cfg, *,
                 cost_model: Optional[CostModel] = None,
                 n_devices: int = 1):
        self.g = g
        self.cfg = cfg
        self.cost = cost_model or load_calibration()
        self.n_devices = max(1, int(n_devices))
        self.block_order = root_block_order(
            g, cfg.match.root_block, getattr(cfg, "root_order", "degree"))
        self.n_blocks = int(self.block_order.shape[0])

    # -- geometry -----------------------------------------------------------
    def derive_match(self, k: int,
                     prev: Optional[Dict[str, Any]]) -> MatchConfig:
        """Per-level `MatchConfig` from observed occupancy.

        ``prev`` is the previous level's per_level telemetry dict
        (``max_count`` / ``overflowed``).  Only result-preserving knobs
        move (see module docstring): ``cap`` shrinks to
        pow2(max(CAP_HEADROOM · max_count, CAP_FLOOR)) when the previous
        level measured small occupancy without overflow, and ``two_phase``
        is dropped for k == 2 (the only prefix edge is certified by the
        anchor gather itself, so phase 2 has nothing to prune — the extra
        compaction is pure overhead).
        """
        base = self.cfg.match
        cap = base.cap
        if prev is not None and not prev.get("overflowed", False):
            peak = int(prev.get("max_count", 0))
            if peak > 0:
                cap = min(base.cap,
                          max(_pow2_ceil(CAP_HEADROOM * peak), CAP_FLOOR))
        two_phase = bool(base.two_phase and k >= 3)
        if cap == base.cap and two_phase == base.two_phase:
            return base
        return dataclasses.replace(base, cap=cap, two_phase=two_phase)

    # -- bucketing ----------------------------------------------------------
    def choose_bucket(self, n_patterns: int) -> int:
        """Pattern-bucket ceiling for one level.

        Monotone in ``n_patterns`` (more candidates never picks a smaller
        bucket — unit-tested) and capped by ``cfg.batch_patterns``, the
        transient-memory ceiling the config already owns.
        """
        if n_patterns <= 1:
            return 1
        return int(min(_pow2_ceil(n_patterns), self.cfg.batch_patterns))

    # -- level costs --------------------------------------------------------
    def _level_costs(self, sizes: List[Tuple[int, int]], match: MatchConfig,
                     max_batch: int) -> Dict[str, float]:
        """Predicted per-block cost of one level under each plane.

        ``sizes`` = (group size, k) pairs of the level (mixed-k levels
        under edge-extension generation contribute one term per group).
        Costs are per root block — the block count multiplies every plane
        equally, so it cancels out of the comparison.
        """
        metric = self.cfg.metric
        seq = bat = 0.0
        for sz, k in sizes:
            seq += sz * self.cost.block_step_s(match, k, 1, batched=False,
                                               metric=metric)
            full, rem = divmod(sz, max_batch)
            for bucket_n in [max_batch] * full + ([rem] if rem else []):
                # _pow2_ceil IS batched._bucket_size — the estimate prices
                # the real padded bucket _mine_group will run
                bat += self.cost.block_step_s(match, k,
                                              _pow2_ceil(bucket_n),
                                              batched=True, metric=metric)
        costs = {"sequential": seq, "batched": bat}
        if self.n_devices > 1:
            # roots shard over the mesh: ndev blocks advance per step, at
            # one extra dispatch-overhead's worth of collective latency
            costs["distributed"] = (bat + self.cost.dispatch_overhead_s
                                    ) / self.n_devices
        return costs

    # -- the decision -------------------------------------------------------
    def plan_level(self, level: int, patterns: Sequence, taus: Sequence[int],
                   prev: Optional[Dict[str, Any]] = None) -> LevelPlan:
        """Plan one level given its candidate set and last level's telemetry.

        Forced execution modes return the config's plane/geometry verbatim.
        ``"auto"`` derives geometry from ``prev`` (see `derive_match`),
        sizes the bucket, and picks the cheapest plane under the cost
        model; ``mis_exact`` always plans sequential (its MIS solve is
        host-side — though its embedding *collection* is batched over
        blocks, see `batched.collect_pattern_embeddings`).  The
        distributed plane is only eligible when the caller pinned a
        mesh-invariant super-block schedule (``cfg.blocks_per_super``) and
        the metric is ``mis_luby`` — without those, auto silently changing
        accounting granularity would break the forced-plane equivalence.
        """
        cfg = self.cfg
        if cfg.execution == "sampled":
            return self._plan_sampled(level, patterns, taus, prev)
        if cfg.execution != "auto":
            return LevelPlan(plane=cfg.execution, match=cfg.match,
                             max_batch=cfg.batch_patterns)
        if not patterns or cfg.metric == "mis_exact":
            return LevelPlan(plane="sequential",
                             match=self.derive_match(
                                 max((p.k for p in patterns), default=2),
                                 prev),
                             max_batch=cfg.batch_patterns)

        match = self.derive_match(max(p.k for p in patterns), prev)
        # same-k group sizes, mirroring batched.level_groups' slicing
        by_k: Dict[int, int] = {}
        for p in patterns:
            by_k[p.k] = by_k.get(p.k, 0) + 1
        max_batch = self.choose_bucket(max(by_k.values()))
        sizes = sorted(by_k.items())
        costs = self._level_costs([(sz, k) for k, sz in sizes], match,
                                  max_batch)

        plane = "sequential" if costs["sequential"] <= costs["batched"] \
            else "batched"
        if ("distributed" in costs
                and cfg.metric == "mis_luby"
                and cfg.blocks_per_super is not None
                and self.n_blocks >= 2 * self.n_devices
                and costs["distributed"] < costs[plane]):
            plane = "distributed"
        if plane == "batched":
            sample, pricing = self._price_sampled(
                level, taus, prev, match,
                [(sz, k) for k, sz in sizes], max_batch, costs["batched"])
            if pricing is not None and pricing["chosen"] == "sampled":
                return LevelPlan(plane="sampled", match=match,
                                 max_batch=max_batch, sample=sample,
                                 pricing=pricing)
            if pricing is not None:
                return LevelPlan(plane="batched", match=match,
                                 max_batch=max_batch, pricing=pricing)
        return LevelPlan(plane=plane, match=match, max_batch=max_batch)

    # -- auto sampled pricing -----------------------------------------------
    def _predict_escalation(self, prev: Optional[Dict[str, Any]]
                            ) -> Tuple[float, str]:
        """Predicted escalation mass E[esc] for the next level's sample.

        Predictor chain, most-informed first:

          * ``"telemetry"`` — the previous level ran sampled: its measured
            escalated/(escalated+pruned) classification split is the best
            available estimate of how separable supports are from τ;
          * ``"frontier"`` — the previous level's frequent/searched ratio:
            frequent parents spawn candidates whose supports sit near τ
            (they escalate); the infrequent rest prune at the prior's rate;
          * ``"prior"`` — `CostModel.esc_prior()` (the measured per-run
            fraction when calibrated, ESCALATION_PRIOR otherwise).
        """
        prior = self.cost.esc_prior()
        if prev is not None:
            s = prev.get("sampled")
            if s is not None and not s.get("exact", False):
                classified = int(s.get("escalated", 0)) + int(
                    s.get("pruned", 0))
                if classified > 0:
                    return (int(s.get("escalated", 0)) / classified,
                            "telemetry")
            searched = int(prev.get("searched", 0))
            if searched > 0:
                freq = min(1.0, int(prev.get("frequent", 0)) / searched)
                return min(1.0, freq + prior * (1.0 - freq)), "frontier"
        return prior, "prior"

    def _price_sampled(self, level: int, taus: Sequence[int],
                       prev: Optional[Dict[str, Any]], match: MatchConfig,
                       sizes: List[Tuple[int, int]], max_batch: int,
                       batched_s: float
                       ) -> Tuple[Optional[Dict[str, Any]],
                                  Optional[Dict[str, Any]]]:
        """Price a sampled pass for one auto level; returns (sample, pricing).

        (None, None) when the level is ineligible (non-batchable metric,
        escalation disabled, complete run, too few blocks, or τ below the
        hidden-mass bound — where even zero-support patterns escalate).
        Otherwise the pricing dict records every decision input plus
        ``"chosen"``; the sample dict is the recorded draw when sampled won.

        The sampled row prices three phases against the batched row:
        ``f·batched`` (the sample pass), ``E[esc]·(1−f)·batched`` (match
        steps over the unsampled schedule) and ``E[esc]·f·replay``
        (update-only replay of the recorded sample blocks, keeping the
        schedule permutation intact) — sampled wins only under
        `SAMPLED_MARGIN`.
        """
        cfg = self.cfg
        m = self.n_blocks
        from .batched import _BATCHABLE_METRICS
        if (cfg.metric not in _BATCHABLE_METRICS or cfg.complete
                or not getattr(cfg, "escalate", True)
                or m < MIN_SAMPLED_BLOCKS or not taus):
            return None, None
        f = min(1.0, max(1, math.ceil(cfg.sample_fraction * m)) / m)
        if f >= 1.0:
            return None, None
        hidden = hidden_mass_bound(cfg.confidence, f)
        tau_min = int(min(taus))
        esc, esc_source = self._predict_escalation(prev)
        rep = 0.0
        for sz, k in sizes:
            full, r = divmod(sz, max_batch)
            for bucket_n in [max_batch] * full + ([r] if r else []):
                rep += self.cost.replay_step_s(match, k,
                                               _pow2_ceil(bucket_n),
                                               metric=cfg.metric)
        # all terms are per root block (`_level_costs` normalizes — the
        # block count multiplies every row equally): the sample pass runs
        # f of the blocks, escalation matches the unsampled (1−f) and
        # replays the sampled f with the cheap update-only step
        sampled_s = batched_s * f \
            + esc * (batched_s * (1.0 - f) + rep * f)
        pricing = {
            "batched_s": float(batched_s), "sampled_s": float(sampled_s),
            "replay_s": float(rep), "fraction": float(f),
            "esc": float(esc), "esc_source": esc_source,
            "margin": SAMPLED_MARGIN, "tau_min": tau_min,
            "hidden_bound": float(hidden),
        }
        if tau_min <= hidden or sampled_s >= SAMPLED_MARGIN * batched_s:
            pricing["chosen"] = "batched"
            return None, pricing
        sample = self._draw_block_sample(level, prev, match,
                                         cfg.sample_fraction)
        pricing["chosen"] = "sampled"
        return sample, pricing

    # -- sampled plane ------------------------------------------------------
    def _plan_sampled(self, level: int, patterns: Sequence,
                      taus: Sequence[int],
                      prev: Optional[Dict[str, Any]]) -> LevelPlan:
        """Draw (and record) one level's root-block sample.

        Forced geometry — ``execution="sampled"`` is an accuracy/latency
        dial over the *batched* plane, so it keeps the config's match/
        bucket verbatim (like every forced mode) and only decides the
        block draw.  The draw is systematic PPS (Madow) over the level's
        block *schedule*: weights come from the previous level's per-block
        peak-occupancy telemetry (``prev["block_peaks"]``, block-id
        indexed, re-ordered by the schedule) with the degree stat as the
        k = 2 fallback; the single uniform comes from a counter-based
        generator keyed on (``sample_seed``, level), so the draw is a pure
        function of (graph, config, level, telemetry) — which is what lets
        a resume replay it bit-identically from the recorded plan.

        Degenerate cases plan the exact batched plane outright: empty
        levels, ``complete=True`` (every block must run anyway), and
        levels with fewer than `MIN_SAMPLED_BLOCKS` blocks.  A fraction
        that rounds up to full coverage keeps the sampled plane but with a
        unit-probability sample — `evaluate_level_sampled` recognises it
        and degenerates to the exact pass with zero escalations.
        """
        from . import sampled as sampled_lib

        cfg = self.cfg
        match, max_batch = cfg.match, cfg.batch_patterns
        m = self.n_blocks
        if not patterns or cfg.complete or m < MIN_SAMPLED_BLOCKS:
            return LevelPlan(plane="batched", match=match,
                             max_batch=max_batch)

        key = sampled_lib.sample_key(cfg.sample_seed, level)
        n_sample = max(1, math.ceil(cfg.sample_fraction * m))
        # cost-model row for the sample pass: f·batched plus the expected
        # exact re-spend esc_prior·(1−f)·batched.  With the prior < 1 this
        # never exceeds the batched row, but the guard keeps the plane
        # honest should the prior ever be calibrated past 1.
        by_k: Dict[int, int] = {}
        for p in patterns:
            by_k[p.k] = by_k.get(p.k, 0) + 1
        costs = self._level_costs([(sz, k) for k, sz in sorted(by_k.items())],
                                  match, self.choose_bucket(max(by_k.values())))
        f = n_sample / m
        sampled_cost = costs["batched"] * (f + self.cost.esc_prior()
                                           * (1.0 - f))
        if sampled_cost > costs["batched"]:
            return LevelPlan(plane="batched", match=match,
                             max_batch=max_batch)
        if n_sample >= m:
            sample = {"fraction": 1.0, "n_sample": int(m),
                      "n_requested": int(m),
                      "positions": list(range(m)), "pis": [1.0] * m,
                      "key": key, "weights": "full", "w": [1.0] * m}
            return LevelPlan(plane="sampled", match=match,
                             max_batch=max_batch, sample=sample)
        sample = self._draw_block_sample(level, prev, match,
                                         cfg.sample_fraction)
        return LevelPlan(plane="sampled", match=match, max_batch=max_batch,
                         sample=sample)

    def _draw_block_sample(self, level: int, prev: Optional[Dict[str, Any]],
                           match: MatchConfig,
                           fraction: float) -> Dict[str, Any]:
        """One level's recorded systematic-PPS block draw (round 0).

        Weights come from the previous level's per-block peak-occupancy
        telemetry (``prev["block_peaks"]``, block-id indexed, re-ordered by
        the schedule) with the degree stat as the k = 2 fallback, floored
        at 1 so zero-yield blocks keep nonzero inclusion probability (the
        HT estimator needs π > 0 everywhere it might observe mass).  The
        full schedule-ordered weight vector is recorded as ``"w"`` — the
        adaptive rounds (`sampled.evaluate_level_sampled`) redraw from it
        via conditional PPS, so a recorded plan is self-contained.
        """
        from . import sampled as sampled_lib

        cfg = self.cfg
        m = self.n_blocks
        key = sampled_lib.sample_key(cfg.sample_seed, level)
        n_sample = min(m, max(1, math.ceil(fraction * m)))
        peaks = None if prev is None else prev.get("block_peaks")
        if peaks is not None and len(peaks) == m:
            # block-id indexed telemetry → schedule order
            w = np.asarray(peaks, np.float64)[self.block_order]
            weights_src = "occupancy"
        else:
            w = block_degree_stat(
                self.g, match.root_block).astype(np.float64)[self.block_order]
            weights_src = "degree"
        w = np.maximum(w, 1.0)
        u = sampled_lib.sample_uniform(key)
        positions, pis = sampled_lib.systematic_sample(w, n_sample, u)
        return {
            "fraction": float(fraction),
            "n_sample": int(positions.shape[0]),
            "n_requested": int(n_sample),
            "positions": [int(x) for x in positions],
            "pis": [float(x) for x in pis],
            "key": key,
            "weights": weights_src,
            "w": [float(x) for x in w],
        }
