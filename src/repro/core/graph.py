"""Data-graph representation for FLEXIS.

The data graph is stored as a pair of CSR structures (out- and in-adjacency)
plus a sorted edge-key array for O(log E) vectorized edge-existence queries.
All arrays are plain numpy on the host; `DeviceGraph` holds the jnp mirrors
used by the matcher. Shapes are static — the matcher never sees ragged data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = ["DataGraph", "DeviceGraph", "build_graph"]


@dataclasses.dataclass(frozen=True)
class DataGraph:
    """Host-side CSR data graph (directed, vertex-labeled).

    Attributes:
      n:          number of vertices.
      labels:     (n,) int32 vertex labels in [0, n_labels).
      out_indptr: (n+1,) int64 CSR row pointers, out-edges.
      out_indices:(E,)  int32 column indices, sorted within each row.
      in_indptr / in_indices: same for the transposed graph.
      edge_keys:  (E,) int64 sorted array of u * n + v for every edge (u, v).
      n_labels:   number of distinct vertex labels.
    """

    n: int
    labels: np.ndarray
    out_indptr: np.ndarray
    out_indices: np.ndarray
    in_indptr: np.ndarray
    in_indices: np.ndarray
    edge_keys: np.ndarray
    n_labels: int
    undirected: bool = False

    @property
    def n_edges(self) -> int:
        return int(self.out_indices.shape[0])

    @property
    def max_out_degree(self) -> int:
        return int(np.max(np.diff(self.out_indptr))) if self.n else 0

    @property
    def max_in_degree(self) -> int:
        return int(np.max(np.diff(self.in_indptr))) if self.n else 0

    def out_degree(self, v: int) -> int:
        return int(self.out_indptr[v + 1] - self.out_indptr[v])

    def neighbors_out(self, v: int) -> np.ndarray:
        return self.out_indices[self.out_indptr[v]: self.out_indptr[v + 1]]

    def neighbors_in(self, v: int) -> np.ndarray:
        return self.in_indices[self.in_indptr[v]: self.in_indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        key = np.int64(u) * self.n + v
        i = np.searchsorted(self.edge_keys, key)
        return bool(i < self.edge_keys.shape[0] and self.edge_keys[i] == key)

    def label_counts(self) -> np.ndarray:
        return np.bincount(self.labels, minlength=self.n_labels)

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.labels,
                self.out_indptr,
                self.out_indices,
                self.in_indptr,
                self.in_indices,
                self.edge_keys,
            )
        )


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """jnp mirror of `DataGraph` consumed by the jitted matcher.

    Edge-existence queries use a bounded binary search over the CSR rows
    (int32 only) — no int64 edge-key table is shipped to the device.
    """

    n: int
    labels: jnp.ndarray
    out_indptr: jnp.ndarray
    out_indices: jnp.ndarray
    in_indptr: jnp.ndarray
    in_indices: jnp.ndarray

    @classmethod
    def from_host(cls, g: DataGraph) -> "DeviceGraph":
        if g.n_edges > np.iinfo(np.int32).max:
            raise ValueError("graphs beyond int32 edge counts must be sharded first")
        out_indices, in_indices = g.out_indices, g.in_indices
        if g.n_edges == 0:
            # edgeless graph: keep index arrays non-empty so the matcher's
            # gathers stay well-formed; the sentinel is unreachable because
            # every degree is 0 (indptr is all zeros).
            out_indices = in_indices = np.zeros(1, np.int32)
        return cls(
            n=g.n,
            labels=jnp.asarray(g.labels, jnp.int32),
            out_indptr=jnp.asarray(g.out_indptr, jnp.int32),
            out_indices=jnp.asarray(out_indices, jnp.int32),
            in_indptr=jnp.asarray(g.in_indptr, jnp.int32),
            in_indices=jnp.asarray(in_indices, jnp.int32),
        )


def _csr_from_edges(n: int, src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int32)


def build_graph(
    n: int,
    edges: Sequence[Tuple[int, int]] | np.ndarray,
    labels: Sequence[int] | np.ndarray,
    *,
    undirected: bool = False,
    n_labels: Optional[int] = None,
) -> DataGraph:
    """Build a `DataGraph` from an edge list.

    Self-loops and duplicate edges are dropped. If `undirected`, every edge is
    inserted in both directions (the paper's loader is undirected while its
    matcher is directed — symmetrizing reproduces that behaviour exactly).
    """
    labels = np.asarray(labels, dtype=np.int32)
    if labels.shape != (n,):
        raise ValueError(f"labels must have shape ({n},), got {labels.shape}")
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size:
        if edges.min() < 0 or edges.max() >= n:
            raise ValueError("edge endpoint out of range")
    src, dst = edges[:, 0], edges[:, 1]
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    # dedupe
    keys = src * n + dst
    keys = np.unique(keys)
    src, dst = keys // n, keys % n
    out_indptr, out_indices = _csr_from_edges(n, src, dst)
    in_indptr, in_indices = _csr_from_edges(n, dst, src)
    return DataGraph(
        n=n,
        labels=labels,
        out_indptr=out_indptr,
        out_indices=out_indices,
        in_indptr=in_indptr,
        in_indices=in_indices,
        edge_keys=np.sort(keys),
        n_labels=int(n_labels if n_labels is not None else (labels.max() + 1 if n else 0)),
        undirected=undirected,
    )
