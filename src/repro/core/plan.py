"""Matching plans — compile a Pattern into static arrays for the JAX matcher.

VF3-Light picks its matching order dynamically during DFS.  On a TPU the
matcher is a fixed dataflow program, so the order is planned here, once per
pattern, on the host:

  * root   = the pattern vertex with the rarest label in the data graph
             (tie-break: max degree) — smallest initial frontier;
  * order  = greedy connected extension, at each step choosing the vertex
             with the most edges into the ordered prefix (max constraints ⇒
             max pruning), tie-break rare label then high degree;
  * anchor = for each non-root vertex, one already-ordered neighbor whose
             adjacency list is gathered to enumerate candidates.

All plan fields are *data* (jnp arrays), not static attributes, so the jitted
matcher compiles once per pattern size k and is reused across every pattern
of that size — crucial when a mining level evaluates hundreds of candidates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .graph import DataGraph
from .pattern import Pattern

__all__ = ["PatternPlan", "make_plan", "stack_plans"]


@dataclasses.dataclass(frozen=True)
class PatternPlan:
    """Device-side matching plan for one pattern.

    k:            pattern size (the only static field).
    root_label:   int32 scalar.
    root_min_out / root_min_in: degree filters for the root.
    anchor_pos:   (k,) int32 — position (into `order`) of the anchor for step
                  i (entry 0 unused).
    anchor_out:   (k,) bool — gather anchor's out-neighbors (else in-).
    cand_label:   (k,) int32 — required label of step-i candidate.
    min_out/min_in: (k,) int32 — degree filters per step.
    check_out:    (k, k) bool — step i must verify edge cand → emb[j].
    check_in:     (k, k) bool — step i must verify edge emb[j] → cand.
    """

    k: int
    root_label: jnp.ndarray
    root_min_out: jnp.ndarray
    root_min_in: jnp.ndarray
    anchor_pos: jnp.ndarray
    anchor_out: jnp.ndarray
    cand_label: jnp.ndarray
    min_out: jnp.ndarray
    min_in: jnp.ndarray
    check_out: jnp.ndarray
    check_in: jnp.ndarray
    order: tuple  # host-side: order[i] = original pattern vertex at step i


def stack_plans(plans: Sequence[PatternPlan]) -> PatternPlan:
    """Stack same-k plans into one plan pytree with a leading pattern axis.

    The per-plan host-side ``order`` metadata is dropped (set to ``()``) so
    every stacked plan of a given k shares one treedef — jit programs keyed on
    the plan pytree then cache-hit across levels instead of retracing per
    stack.
    """
    assert len(plans) > 0, "cannot stack zero plans"
    k = plans[0].k
    assert all(p.k == k for p in plans), "plans must share pattern size"
    leaves = [jax.tree_util.tree_flatten(p)[0] for p in plans]
    stacked = [jnp.stack([ln[i] for ln in leaves]) for i in range(len(leaves[0]))]
    return PatternPlan(k, *stacked, order=())


def make_plan(pat: Pattern, graph: Optional[DataGraph] = None) -> PatternPlan:
    if not pat.is_connected():
        raise ValueError("can only plan connected patterns")
    k = pat.k
    und = pat.undirected_adj()
    out_deg = pat.adj.sum(axis=1).astype(np.int32)
    in_deg = pat.adj.sum(axis=0).astype(np.int32)

    if graph is not None:
        label_freq = graph.label_counts()
        rarity = label_freq[np.clip(pat.labels, 0, label_freq.shape[0] - 1)]
    else:
        rarity = np.zeros(k, dtype=np.int64)

    # --- choose order -------------------------------------------------------
    total_deg = und.sum(axis=0)
    root = int(np.lexsort((-total_deg, rarity))[0])
    order = [root]
    remaining = set(range(k)) - {root}
    while remaining:
        best, best_key = None, None
        for v in remaining:
            conn = int(sum(und[v, u] for u in order))
            if conn == 0:
                continue
            key = (-conn, int(rarity[v]), -int(total_deg[v]))
            if best_key is None or key < best_key:
                best, best_key = v, key
        assert best is not None, "pattern connected but no extension found"
        order.append(best)
        remaining.remove(best)

    pos_of = {v: i for i, v in enumerate(order)}

    # --- anchors + checks ---------------------------------------------------
    anchor_pos = np.zeros(k, dtype=np.int32)
    anchor_out = np.zeros(k, dtype=bool)
    check_out = np.zeros((k, k), dtype=bool)
    check_in = np.zeros((k, k), dtype=bool)
    for i in range(1, k):
        v = order[i]
        # candidate anchors = ordered neighbors; prefer one with a pattern
        # edge anchor→v (out-gather), tie-break earliest (smallest frontier
        # growth history)
        anchors = [j for j in range(i) if und[order[j], v]]
        outs = [j for j in anchors if pat.adj[order[j], v]]
        if outs:
            a = outs[0]
            anchor_pos[i], anchor_out[i] = a, True
        else:
            a = anchors[0]
            anchor_pos[i], anchor_out[i] = a, False
        for j in range(i):
            u = order[j]
            need_in = bool(pat.adj[u, v])   # emb[j] → cand
            need_out = bool(pat.adj[v, u])  # cand → emb[j]
            # the gather itself certifies the anchor edge in gather direction
            if j == a:
                if anchor_out[i]:
                    need_in = False  # anchor→cand guaranteed by out-gather
                else:
                    need_out = False  # cand→anchor guaranteed by in-gather
            check_in[i, j] = need_in
            check_out[i, j] = need_out

    labels_o = pat.labels[order]
    out_o = out_deg[order]
    in_o = in_deg[order]
    return PatternPlan(
        k=k,
        root_label=jnp.int32(labels_o[0]),
        root_min_out=jnp.int32(out_o[0]),
        root_min_in=jnp.int32(in_o[0]),
        anchor_pos=jnp.asarray(anchor_pos),
        anchor_out=jnp.asarray(anchor_out),
        cand_label=jnp.asarray(labels_o, jnp.int32),
        min_out=jnp.asarray(out_o, jnp.int32),
        min_in=jnp.asarray(in_o, jnp.int32),
        check_out=jnp.asarray(check_out),
        check_in=jnp.asarray(check_in),
        order=tuple(order),
    )
