"""Support metrics: MNI, Fractional-score, exact MIS, plus host oracles.

The device-side updates consume the matcher's embedding blocks; the exact
MIS (NP-hard, gold standard) runs on the host over the materialized conflict
graph and is used by tests/benchmarks only — precisely how the paper treats
it (§2.4: accurate but too expensive for production).

Contract for the batched data plane (``core/batched.py``): every update
here is pure dataflow over its state array, so it ``vmap``s over a leading
pattern axis — (P, k, n) image/count tables — with per-pattern results
identical to P independent sequential updates.  Keep new metrics free of
host-side control flow for this to hold.
"""
from __future__ import annotations

import functools
import itertools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import DataGraph
from .pattern import Pattern

__all__ = [
    "mni_init",
    "mni_update",
    "mni_value",
    "frac_init",
    "frac_update",
    "frac_value",
    "exact_mis",
    "greedy_mis_host",
    "enumerate_embeddings_host",
]


# ---------------------------------------------------------------------------
# MNI (GraMi / T-FSM-MNI): per-pattern-vertex distinct image counts, min.
# ---------------------------------------------------------------------------

def mni_init(k: int, n: int) -> jnp.ndarray:
    """(k, n) bool image table — images[v, d] ⇔ some embedding maps v → d."""
    return jnp.zeros((k, n), dtype=jnp.bool_)


@functools.partial(jax.jit, static_argnames=("k",))
def mni_update(images: jnp.ndarray, emb: jnp.ndarray, n_valid: jnp.ndarray, k: int):
    cap = emb.shape[0]
    valid = jnp.arange(cap, dtype=jnp.int32) < n_valid
    vs = jnp.clip(emb[:, :k], 0, None)  # (cap, k)
    rows = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, :], vs.shape)
    # scatter-OR: max of bools, masked rows contribute False (no erase)
    return images.at[rows, vs].max(valid[:, None])


@jax.jit
def mni_value(images: jnp.ndarray) -> jnp.ndarray:
    return jnp.min(jnp.sum(images, axis=1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fractional-score (T-FSM): down-weight contested data vertices.
#
# Our formulation (documented variant, DESIGN.md §6): count c[v, d] =
# #embeddings mapping pattern vertex v to data vertex d; a data vertex d's
# total load t[d] = Σ_v c[v, d]; the fractional image mass of v is
# Σ_d c[v, d] / t[d] (each data vertex distributes one unit of support among
# the embeddings contesting it).  Support = min_v mass(v).  Properties kept
# from T-FSM: ≤ MNI always; = MNI when no data vertex is shared.
# ---------------------------------------------------------------------------

def frac_init(k: int, n: int) -> jnp.ndarray:
    return jnp.zeros((k, n), dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("k",))
def frac_update(counts: jnp.ndarray, emb: jnp.ndarray, n_valid: jnp.ndarray, k: int):
    cap = emb.shape[0]
    valid = jnp.arange(cap, dtype=jnp.int32) < n_valid
    vs = jnp.clip(emb[:, :k], 0, None)
    rows = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, :], vs.shape)
    return counts.at[rows, vs].add(valid[:, None].astype(jnp.float32))


@jax.jit
def frac_value(counts: jnp.ndarray) -> jnp.ndarray:
    total = jnp.sum(counts, axis=0, keepdims=True)  # (1, n)
    share = jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)
    return jnp.min(jnp.sum(share, axis=1))


# ---------------------------------------------------------------------------
# Exact MIS over the embedding conflict graph (host, branch & bound).
# ---------------------------------------------------------------------------

def _conflict_adj(embs: np.ndarray) -> List[int]:
    """Bitmask adjacency of the conflict graph (embeddings sharing a vertex)."""
    m = embs.shape[0]
    adj = [0] * m
    sets = [frozenset(row.tolist()) for row in embs]
    for i in range(m):
        for j in range(i + 1, m):
            if sets[i] & sets[j]:
                adj[i] |= 1 << j
                adj[j] |= 1 << i
    return adj


def exact_mis(embs: np.ndarray, limit: int = 10**7) -> int:
    """Maximum independent set size of the embedding conflict graph.

    Branch and bound with greedy lower bound + remaining-count upper bound.
    `limit` caps explored nodes (raises if exceeded — tests use small sets).
    """
    embs = np.asarray(embs)
    m = embs.shape[0]
    if m == 0:
        return 0
    if m > 60:
        # group identical-vertex-set duplicates first
        uniq = {tuple(sorted(r.tolist())) for r in embs}
        embs = np.array(sorted(uniq))
        m = embs.shape[0]
        if m > 60:
            raise ValueError(f"exact MIS limited to 60 embeddings, got {m}")
    adj = _conflict_adj(embs)
    full = (1 << m) - 1
    best = 0
    nodes = 0

    def bb(avail: int, size: int):
        nonlocal best, nodes
        nodes += 1
        if nodes > limit:
            raise RuntimeError("exact_mis node limit exceeded")
        if size + bin(avail).count("1") <= best:
            return
        if avail == 0:
            best = max(best, size)
            return
        v = (avail & -avail).bit_length() - 1  # lowest set bit
        # branch 1: take v
        bb(avail & ~adj[v] & ~(1 << v), size + 1)
        # branch 2: skip v
        bb(avail & ~(1 << v), size)

    bb(full, 0)
    return best


def greedy_mis_host(embs: np.ndarray) -> List[int]:
    """Lexicographically-first maximal independent set (host oracle)."""
    used: set = set()
    picked = []
    for i, row in enumerate(np.asarray(embs)):
        vs = set(int(v) for v in row)
        if not (vs & used):
            used |= vs
            picked.append(i)
    return picked


# ---------------------------------------------------------------------------
# Brute-force embedding enumeration (host oracle for matcher tests).
# ---------------------------------------------------------------------------

def enumerate_embeddings_host(g: DataGraph, pat: Pattern, cap: int = 10**6) -> np.ndarray:
    """All injective label/edge-preserving mappings pattern → data graph.

    Subgraph-isomorphism semantics per the paper §2.1.4: pattern edges must
    exist in the data graph; extra data-graph edges between images are fine.
    Returns (M, k) int32 rows ordered lexicographically by image tuple.
    """
    k = pat.k
    cands = [np.nonzero(g.labels == pat.labels[v])[0] for v in range(k)]
    out: List[Tuple[int, ...]] = []

    def extend(partial: List[int]):
        i = len(partial)
        if i == k:
            out.append(tuple(partial))
            return
        for d in cands[i]:
            d = int(d)
            if d in partial:
                continue
            ok = True
            for j in range(i):
                if pat.adj[j, i] and not g.has_edge(partial[j], d):
                    ok = False
                    break
                if pat.adj[i, j] and not g.has_edge(d, partial[j]):
                    ok = False
                    break
            if ok:
                extend(partial + [d])
                if len(out) >= cap:
                    raise RuntimeError("embedding cap exceeded")

    extend([])
    return np.array(out, dtype=np.int32).reshape(-1, k)
