"""Decoder-only transformer covering all five assigned LM architectures.

Layers are *scanned* (params stacked on a leading axis) so 46-layer configs
compile as one loop — with optional per-layer remat.  Alternating
local/global stacks (gemma2) scan over (local, global) layer *pairs* so the
scan body stays uniform.  Dense-FFN and MoE variants share the block.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    AttentionConfig,
    attention_init,
    init_cache,
    mha_decode,
    mha_train,
)
from .common import (
    dense_apply,
    dense_init,
    embed_init,
    rmsnorm_apply,
    rmsnorm_init,
    softcap,
)
from .moe import MoEConfig, moe_apply, moe_init
from .sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int                          # dense-FFN hidden (ignored if MoE)
    # --- MoE (n_experts == 0 → dense) ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # --- attention variant ---
    window: Optional[int] = None       # sliding window (all layers)
    local_global: bool = False         # alternate local(window)/global layers
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_base: float = 10000.0
    # --- execution ---
    remat: bool = True
    use_flash: bool = False
    attn_impl: str = "dense"           # "dense" | "chunked" (flash-style scan)
    q_chunk: int = 512
    kv_chunk: int = 1024
    scan_layers: bool = True           # False: unrolled python loop (used by
                                       # the dry-run cost calibration — XLA
                                       # cost analysis counts while bodies once)
    dtype: Any = jnp.bfloat16

    def attn_cfg(self, *, local: bool) -> AttentionConfig:
        win = self.window if (local or not self.local_global) else None
        if not self.local_global and self.window is None:
            win = None
        return AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            rope_base=self.rope_base,
            qk_norm=self.qk_norm,
            logit_softcap=self.attn_softcap,
            window=win,
            use_flash=self.use_flash,
        )

    @property
    def moe_cfg(self) -> Optional[MoEConfig]:
        if self.n_experts == 0:
            return None
        return MoEConfig(self.d_model, self.moe_d_ff, self.n_experts,
                         self.top_k, capacity_factor=self.moe_capacity_factor)

    @property
    def layers_per_step(self) -> int:
        return 2 if self.local_global else 1

    @property
    def n_scan_steps(self) -> int:
        assert self.n_layers % self.layers_per_step == 0
        return self.n_layers // self.layers_per_step

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), for 6·N·D."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads * 2) + d * hd * (self.n_kv_heads * 2)
        if self.n_experts:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.vocab * d + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff
        return full - inactive


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _ffn_init(rng, cfg: TransformerConfig) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "wi": dense_init(ks[0], cfg.d_model, cfg.d_ff),
        "wg": dense_init(ks[1], cfg.d_model, cfg.d_ff),
        "wo": dense_init(ks[2], cfg.d_ff, cfg.d_model),
    }


def _block_init(rng, cfg: TransformerConfig, *, local: bool) -> Params:
    ks = jax.random.split(rng, 2)
    p: Params = {
        "ln_attn": rmsnorm_init(cfg.d_model),
        "ln_ffn": rmsnorm_init(cfg.d_model),
        "attn": attention_init(ks[0], cfg.attn_cfg(local=local)),
    }
    if cfg.moe_cfg is not None:
        p["moe"] = moe_init(ks[1], cfg.moe_cfg)
    else:
        p["ffn"] = _ffn_init(ks[1], cfg)
    return p


def _step_init(rng, cfg: TransformerConfig) -> Params:
    """One scan step = one block, or a (local, global) pair."""
    if cfg.local_global:
        k1, k2 = jax.random.split(rng)
        return {
            "local": _block_init(k1, cfg, local=True),
            "global": _block_init(k2, cfg, local=False),
        }
    return _block_init(rng, cfg, local=False)


def transformer_init(rng, cfg: TransformerConfig) -> Params:
    k_embed, k_layers = jax.random.split(rng)
    layer_rngs = jax.random.split(k_layers, cfg.n_scan_steps)
    stacked = jax.vmap(lambda r: _step_init(r, cfg))(layer_rngs)
    return {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_final": rmsnorm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# forward (train)
# ---------------------------------------------------------------------------

def _ffn_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    import os

    h = dense_apply(p["wi"], x)
    g = dense_apply(p["wg"], x)
    if os.environ.get("REPRO_SP_FFN") == "1":
        # perf experiment H1b: keep the FFN sequence-sharded — XLA gathers
        # the (small) weights instead of the (large) activations
        h = constrain(jax.nn.silu(g) * h, "batch", "residual", None)
    else:
        h = constrain(jax.nn.silu(g) * h, "batch", "seq", "mlp")
    return dense_apply(p["wo"], h)


def _block_apply(p: Params, cfg: TransformerConfig, x, positions, *, local: bool):
    a = mha_train(p["attn"], cfg.attn_cfg(local=local),
                  rmsnorm_apply(p["ln_attn"], x), positions,
                  impl=cfg.attn_impl, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    x = constrain(x + a, "batch", "residual", "embed")
    h = rmsnorm_apply(p["ln_ffn"], x)
    if cfg.moe_cfg is not None:
        f, aux = moe_apply(p["moe"], cfg.moe_cfg, h)
    else:
        f, aux = _ffn_apply(p["ffn"], h), jnp.float32(0.0)
    return constrain(x + f, "batch", "residual", "embed"), aux


def transformer_apply(params: Params, cfg: TransformerConfig,
                      tokens: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) int32 → (logits (B, S, V) bf16, aux_loss)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = jnp.take(params["embed"]["table"].astype(cfg.dtype), tokens, axis=0)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    x = constrain(x, "batch", "residual", "embed")

    def step(carry, layer_p):
        x, aux = carry
        if cfg.local_global:
            x, a1 = _block_apply(layer_p["local"], cfg, x, positions, local=True)
            x, a2 = _block_apply(layer_p["global"], cfg, x, positions, local=False)
            return (x, aux + a1 + a2), None
        x, a = _block_apply(layer_p, cfg, x, positions, local=False)
        return (x, aux + a), None

    import os

    policy_name = os.environ.get("REPRO_REMAT_POLICY", "full")
    if not cfg.remat or policy_name == "none":
        step_fn = step
    elif policy_name == "dots":
        # perf experiment H3: save matmul outputs — no recompute (and no
        # re-gather) of the TP-region projections in the backward pass
        step_fn = jax.checkpoint(
            step, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        step_fn = jax.checkpoint(step)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(step_fn, (x, jnp.float32(0.0)),
                                   params["layers"])
    else:
        carry = (x, jnp.float32(0.0))
        for i in range(cfg.n_scan_steps):
            layer_p = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            carry, _ = step_fn(carry, layer_p)
        x, aux = carry
    x = rmsnorm_apply(params["ln_final"], x)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"]["table"].astype(cfg.dtype))
    logits = softcap(logits, cfg.final_softcap)
    return constrain(logits, "batch", "seq", "vocab"), aux


def lm_loss(params: Params, cfg: TransformerConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray, *, aux_weight: float = 0.01) -> jnp.ndarray:
    logits, aux = transformer_apply(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold) + aux_weight * aux


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> Params:
    def one(local: bool):
        return init_cache(cfg.attn_cfg(local=local), batch, max_seq, cfg.dtype)

    def step_cache(_):
        if cfg.local_global:
            return {"local": one(True), "global": one(False)}
        return one(False)

    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_scan_steps,) + x.shape),
        step_cache(None))


def _block_decode(p, cfg, cache, x, position, *, local):
    a, cache = mha_decode(p["attn"], cfg.attn_cfg(local=local), cache,
                          rmsnorm_apply(p["ln_attn"], x), position)
    x = x + a
    h = rmsnorm_apply(p["ln_ffn"], x)
    if cfg.moe_cfg is not None:
        f, _ = moe_apply(p["moe"], cfg.moe_cfg, h)
    else:
        f = _ffn_apply(p["ffn"], h)
    return x + f, cache


def transformer_decode(params: Params, cfg: TransformerConfig, cache: Params,
                       tokens: jnp.ndarray, positions: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1); positions: (B,). Returns
    (logits (B, 1, V), new_cache)."""
    x = jnp.take(params["embed"]["table"].astype(cfg.dtype), tokens, axis=0)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)

    def step(x, xs):
        layer_p, layer_cache = xs
        if cfg.local_global:
            x, c1 = _block_decode(layer_p["local"], cfg, layer_cache["local"],
                                  x, positions, local=True)
            x, c2 = _block_decode(layer_p["global"], cfg, layer_cache["global"],
                                  x, positions, local=False)
            return x, {"local": c1, "global": c2}
        x, c = _block_decode(layer_p, cfg, layer_cache, x, positions, local=False)
        return x, c

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(step, x, (params["layers"], cache))
    else:
        outs = []
        for i in range(cfg.n_scan_steps):
            xs_i = jax.tree_util.tree_map(lambda a: a[i],
                                          (params["layers"], cache))
            x, c_i = step(x, xs_i)
            outs.append(c_i)
        new_cache = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *outs)
    x = rmsnorm_apply(params["ln_final"], x)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"]["table"].astype(cfg.dtype))
    return softcap(logits, cfg.final_softcap), new_cache
