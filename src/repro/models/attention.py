"""Grouped-query attention — covers every assigned LM arch's variant:

  * GQA / MQA (kv_heads ≤ heads)                  [all five]
  * qk-norm (RMS over head_dim)                   [qwen3, qwen3-moe]
  * attention-logit softcap                        [gemma2]
  * sliding-window masks, local/global alternation [gemma2, mixtral]
  * RoPE positions, bf16 compute, fp32 softmax

Train path (full sequence, causal) and decode path (single step against a
static KV cache).  The Pallas flash kernel (`repro.kernels.flash_attention`)
is a drop-in for the train path on TPU; the jnp path below is the oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import (
    apply_rope,
    dense_apply,
    dense_init,
    rmsnorm_apply,
    rmsnorm_init,
    rotary_embedding,
    softcap,
)
from .sharding import constrain, current_mesh, _axis_size

Params = Dict[str, Any]

NEG_INF = -1e30


def _tp_attention(n_heads: int) -> bool:
    """TP (head-sharded) attention when heads divide the model axis;
    otherwise SP (sequence-sharded) attention. Decided at trace time.

    REPRO_ATTN_MODE=sp forces the SP path (perf experiment H1: keep the
    residual stream seq-sharded through attention and gather the small GQA
    K/V instead of the full activations)."""
    import os

    if os.environ.get("REPRO_ATTN_MODE") == "sp":
        return False
    mesh = current_mesh()
    if mesh is None:
        return True
    return n_heads % _axis_size(mesh, "model") == 0


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_base: float = 10000.0
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    window: Optional[int] = None        # sliding-window size (None = full)
    use_flash: bool = False             # route train path through Pallas


def attention_init(rng, cfg: AttentionConfig) -> Params:
    ks = jax.random.split(rng, 5)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p: Params = {
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kv * hd),
        "wv": dense_init(ks[2], d, kv * hd),
        "wo": dense_init(ks[3], h * hd, d, scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _project_qkv(params, cfg: AttentionConfig, x, positions):
    """x: (B, S, D) → q (B,S,H,hd), k/v (B,S,KV,hd), with RoPE + qk-norm."""
    B, S, _ = x.shape
    q = dense_apply(params["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = dense_apply(params["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(params["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        k = rmsnorm_apply(params["k_norm"], k)
    cos, sin = rotary_embedding(positions, cfg.head_dim, cfg.rope_base)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if _tp_attention(cfg.n_heads):
        # Megatron-TP region: heads sharded, sequence gathered (the guard in
        # `constrain` drops the kv-head axis when kv < model axis size)
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)
    else:
        # SP attention: sequence stays sharded, heads replicated (24-head
        # minitron on a 16-way model axis), K/V gathered for the contraction
        q = constrain(q, "batch", "residual", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    return q, k, v


def _mask(S: int, window: Optional[int]) -> jnp.ndarray:
    """(S, S) bool causal (optionally windowed) mask — True = attend."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    return m


def _attn_chunked(q, k, v, cfg: AttentionConfig, q_chunk: int, kv_chunk: int
                  ) -> jnp.ndarray:
    """Blockwise (flash-style) attention in pure jnp — O(S·kv_chunk) memory.

    Only the KV axis is chunked (a sequential `lax.scan` with running
    max/sum/acc).  The query axis stays *spatial*, so under SPMD it remains
    sharded and every chip works on every scan step — chunking q with a scan
    would serialize the mesh.  This is both the memory-feasible lowering for
    the 32k/500k cells and the oracle for the Pallas kernel. `q_chunk` is
    accepted for API compatibility (unused).
    """
    del q_chunk
    B, S, KV, hd = k.shape
    H = q.shape[2]
    groups = H // KV
    scale = 1.0 / np.sqrt(hd)
    nk = -(-S // kv_chunk)
    qg = q.reshape(B, S, KV, groups, hd)
    kr = jnp.moveaxis(k.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    q_pos = jnp.arange(S)

    def kv_block(carry, xs):
        m, l, acc = carry
        ki, kb, vb = xs
        k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, kb).astype(jnp.float32)
        s *= scale
        s = softcap(s, cfg.logit_softcap)
        mask = k_pos[None, :] <= q_pos[:, None]
        if cfg.window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < cfg.window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, groups, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, groups, S), jnp.float32)
    a0 = jnp.zeros((B, KV, groups, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                  (jnp.arange(nk), kr, vr))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.einsum("bkgqh->bqkgh", out).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def mha_train(params: Params, cfg: AttentionConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, impl: str = "dense",
              q_chunk: int = 512, kv_chunk: int = 1024) -> jnp.ndarray:
    """Full-sequence causal attention. x: (B, S, D)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    if cfg.use_flash:
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(
            q, k, v, causal=True, window=cfg.window,
            softcap=cfg.logit_softcap)
    elif impl == "chunked" and S > q_chunk:
        out = _attn_chunked(q, k, v, cfg, min(q_chunk, S), min(kv_chunk, S))
    else:
        groups = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(B, S, cfg.n_kv_heads, groups, cfg.head_dim)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
        scores *= 1.0 / np.sqrt(cfg.head_dim)
        scores = softcap(scores, cfg.logit_softcap)
        mask = _mask(S, cfg.window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        out = out.reshape(B, S, cfg.n_heads, cfg.head_dim)
    if _tp_attention(cfg.n_heads):
        out = constrain(out, "batch", None, "heads", None)
    else:
        out = constrain(out, "batch", "residual", None, None)
    return dense_apply(params["wo"], out.reshape(B, S, -1))


# ---------------------------------------------------------------------------
# Decode path — one new token against a static KV cache.
# ---------------------------------------------------------------------------

def init_cache(cfg: AttentionConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Params:
    """KV cache for one layer. Sliding-window layers allocate only the
    window (rolling buffer) — the sub-quadratic long-context path."""
    length = min(max_seq, cfg.window) if cfg.window is not None else max_seq
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def mha_decode(params: Params, cfg: AttentionConfig, cache: Params,
               x: jnp.ndarray, position: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Params]:
    """x: (B, 1, D); position: (B,) absolute positions. Returns (out, cache).

    The cache sequence axis is sharded over the model axis for long-context
    cells ("kv_seq" rule); the softmax reduction over the sharded axis
    lowers to an all-reduce, keeping per-chip memory ∝ seq/|model|.
    """
    B, one, D = x.shape
    q = dense_apply(params["wq"], x).reshape(B, cfg.n_heads, cfg.head_dim)
    k = dense_apply(params["wk"], x).reshape(B, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(params["wv"], x).reshape(B, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        k = rmsnorm_apply(params["k_norm"], k)
    cos, sin = rotary_embedding(position, cfg.head_dim, cfg.rope_base)  # (B, hd/2)
    q = apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
    k = apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]

    L = cache["k"].shape[1]
    # rolling-buffer slot for windowed layers, append slot otherwise
    slot = jnp.where(jnp.int32(L) > position.astype(jnp.int32),
                     position.astype(jnp.int32),
                     position.astype(jnp.int32) % L) if cfg.window is not None \
        else position.astype(jnp.int32)
    ck = jax.vmap(lambda c, s, val: jax.lax.dynamic_update_slice_in_dim(c, val[None], s, 0)
                  )(cache["k"], slot, k.astype(cache["k"].dtype))
    cv = jax.vmap(lambda c, s, val: jax.lax.dynamic_update_slice_in_dim(c, val[None], s, 0)
                  )(cache["v"], slot, v.astype(cache["v"].dtype))
    ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
    cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, groups, cfg.head_dim)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, ck).astype(jnp.float32)
    scores *= 1.0 / np.sqrt(cfg.head_dim)
    scores = softcap(scores, cfg.logit_softcap)
    # valid cache entries: t ≤ position (append) / all written slots (rolling)
    t = jnp.arange(L)[None, :]
    if cfg.window is not None:
        n_written = jnp.minimum(position + 1, L)[:, None]
        valid = t < n_written
    else:
        valid = t <= position[:, None]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, cv).reshape(B, 1, -1)
    return dense_apply(params["wo"], out), {"k": ck, "v": cv}
