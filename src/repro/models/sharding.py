"""Logical-axis sharding rules (MaxText-style, minimal).

Model code annotates tensors with *logical* axes ("batch", "heads", …);
the launcher installs a rule table mapping logical → mesh axes for the
current mesh.  Outside any mesh (unit tests, single-CPU smoke runs) every
annotation is a no-op, so models run unmodified everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "DEFAULT_RULES", "use_rules", "logical_spec", "constrain",
           "current_mesh"]

AxisRules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Default production rules (see DESIGN.md §4).  "pod" is a pure-DP outer axis.
DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "residual": "model",     # Megatron-SP: residual stream seq-sharded
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "capacity": None,
    "kv_seq": "model",       # decode-time KV cache sequence sharding
    "nodes": ("pod", "data"),  # GNN graphs (full-mesh variant refuted: §Perf)
    "edge_chunk": ("pod", "data"),
    "hidden": None,
    "table_rows": "model",   # DLRM embedding-table row sharding
    "feature": None,
    "roots": ("pod", "data", "model"),  # FLEXIS match roots: whole mesh
}


class _Ctx(threading.local):
    def __init__(self):
        self.rules: Optional[AxisRules] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[AxisRules] = None):
    """Install sharding rules for `mesh` (mesh axes not in the rule target
    are dropped automatically, so the same table serves 2-D and 3-D meshes)."""
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules = dict(DEFAULT_RULES, **(rules or {}))
    _CTX.mesh = mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def logical_spec(*axes: Optional[str]) -> P:
    """PartitionSpec for a sequence of logical axis names (None = replicated)."""
    rules = _CTX.rules or DEFAULT_RULES
    mesh = _CTX.mesh
    names = set(mesh.axis_names) if mesh is not None else set()
    parts = []
    used: set = set()
    for ax in axes:
        tgt = rules.get(ax) if ax is not None else None
        if tgt is None:
            parts.append(None)
            continue
        if isinstance(tgt, str):
            tgt = (tgt,)
        eff = tuple(t for t in tgt if t in names and t not in used)
        used |= set(eff)
        if len(eff) == 0:
            parts.append(None)
        elif len(eff) == 1:
            parts.append(eff[0])
        else:
            parts.append(eff)
    return P(*parts)


def _axis_size(mesh: Mesh, part) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(part, (tuple, list)):
        n = 1
        for p in part:
            n *= sizes.get(p, 1)
        return n
    return sizes.get(part, 1)


def constrain(x, *axes: Optional[str]):
    """Sharding-constrain `x` to logical axes; no-op outside a mesh context.

    Divisibility guard: any logical axis whose mesh extent does not divide
    the corresponding tensor dim is dropped (replicated) instead of forcing
    GSPMD into involuntary-full-rematerialization resharding — e.g. 8 KV
    heads on a 16-way model axis, or 24 query heads on 16 chips.
    """
    if _CTX.mesh is None:
        return x
    mesh = _CTX.mesh
    spec = logical_spec(*axes)
    parts = []
    for dim, part in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if part is not None and dim % _axis_size(mesh, part) != 0:
            part = None
        parts.append(part)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, logical_spec(*axes))
