"""EmbeddingBag — JAX has no native one; this is the RecSys hot path.

take + segment-reduce formulation: bags of indices gather rows from the
(row-sharded) table and reduce within the bag.  Under GSPMD the gather on a
"table_rows"-sharded table lowers to the classic embedding all-to-all; the
Pallas kernel (`repro.kernels.embedding_bag`) is the single-shard fast path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .sharding import constrain

Params = Dict[str, Any]


def embedding_bag_init(rng, n_rows: int, dim: int, *, scale: float = 0.01) -> Params:
    table = scale * jax.random.normal(rng, (n_rows, dim), jnp.float32)
    return {"table": table}


def embedding_bag_apply(params: Params, idx: jnp.ndarray,
                        weights: Optional[jnp.ndarray] = None,
                        *, combiner: str = "sum",
                        dtype=jnp.bfloat16) -> jnp.ndarray:
    """idx: (B, H) int32 bags (H = hots per bag; pad with -1).

    Returns (B, D). combiner ∈ {sum, mean}.
    """
    table = constrain(params["table"].astype(dtype), "table_rows", "feature")
    mask = idx >= 0
    safe = jnp.maximum(idx, 0)
    rows = jnp.take(table, safe, axis=0)                  # (B, H, D)
    if weights is not None:
        rows = rows * weights[..., None].astype(dtype)
    rows = jnp.where(mask[..., None], rows, 0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(mask.sum(axis=1, keepdims=True), 1).astype(dtype)
    return out
