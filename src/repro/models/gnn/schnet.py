"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter convolutions.

Interaction block: W·h_j ⊙ filter(RBF(‖r_i − r_j‖)) summed over neighbors,
with shifted-softplus activations.  Positions come from the batch; for
non-molecular graph cells the launcher synthesizes positions (DESIGN.md §5) —
the kernel regime (RBF + edge gather/scatter) is what the cell exercises.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..common import dense_apply, dense_init
from .common import (
    GraphBatch,
    gather,
    graph_regression_loss,
    mlp_apply,
    mlp_init,
    node_regression_loss,
    scatter_sum,
    segment_pool,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    d_in: int
    d_hidden: int = 64
    n_interactions: int = 3
    n_rbf: int = 300
    cutoff: float = 10.0
    graph_level: bool = True
    n_out: int = 1


def ssp(x):
    """shifted softplus (SchNet's activation)."""
    return jax.nn.softplus(x) - np.log(2.0)


def rbf_expand(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """(E,) distances → (E, n_rbf) Gaussian radial basis."""
    centers = jnp.linspace(0.0, cutoff, n_rbf, dtype=jnp.float32)
    gamma = jnp.float32(10.0 * n_rbf / cutoff**2) / n_rbf
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def schnet_init(rng, cfg: SchNetConfig) -> Params:
    ks = jax.random.split(rng, 2 + 4 * cfg.n_interactions)
    p: Params = {"embed": dense_init(ks[0], cfg.d_in, cfg.d_hidden)}
    for i in range(cfg.n_interactions):
        base = 1 + 4 * i
        p[f"int{i}"] = {
            "filter": mlp_init(ks[base], (cfg.n_rbf, cfg.d_hidden, cfg.d_hidden)),
            "in_proj": dense_init(ks[base + 1], cfg.d_hidden, cfg.d_hidden),
            "out1": dense_init(ks[base + 2], cfg.d_hidden, cfg.d_hidden),
            "out2": dense_init(ks[base + 3], cfg.d_hidden, cfg.d_hidden),
        }
    k_head = jax.random.split(ks[-1])
    p["head"] = mlp_init(k_head[0], (cfg.d_hidden, cfg.d_hidden // 2, cfg.n_out))
    return p


def schnet_apply(params: Params, cfg: SchNetConfig, gb: GraphBatch) -> jnp.ndarray:
    assert gb.pos is not None, "SchNet needs positions"
    n = gb.x.shape[0]
    h = dense_apply(params["embed"], gb.x.astype(jnp.bfloat16))
    rij = gather(gb.pos, gb.edge_src) - gather(gb.pos, gb.edge_dst)
    dist = jnp.linalg.norm(rij.astype(jnp.float32) + 1e-12, axis=-1)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff).astype(jnp.bfloat16)
    # smooth cosine cutoff, applied to the *filter output* (SchNetPack
    # form) so beyond-cutoff edges contribute exactly zero in any dtype
    env = (0.5 * (jnp.cos(np.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
           * (dist < cfg.cutoff)).astype(jnp.bfloat16)

    for i in range(cfg.n_interactions):
        ip = params[f"int{i}"]
        w = mlp_apply(ip["filter"], rbf, act=ssp) * env[:, None]  # (E, H)
        src_feat = gather(dense_apply(ip["in_proj"], h), gb.edge_src)
        msg = src_feat * w
        agg = scatter_sum(msg, gb.edge_dst, gb.edge_mask, n)
        v = ssp(dense_apply(ip["out1"], agg))
        h = h + dense_apply(ip["out2"], v)

    out = mlp_apply(params["head"], h, act=ssp)
    if cfg.graph_level:
        return segment_pool(out, gb.graph_ids, gb.node_mask, gb.n_graphs,
                            mean=False)
    return out


def schnet_loss(params: Params, cfg: SchNetConfig, gb: GraphBatch) -> jnp.ndarray:
    out = schnet_apply(params, cfg, gb)
    if cfg.graph_level:
        return graph_regression_loss(out[:, 0], gb.targets)
    return node_regression_loss(out, gb.targets, gb.node_mask)
