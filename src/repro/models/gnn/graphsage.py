"""GraphSAGE (Hamilton et al., arXiv:1706.02216) — mean aggregator.

h_v^{l+1} = σ(W_self · h_v ⊕ W_neigh · mean_{u∈N(v)} h_u), L2-normalized.
Works full-batch or on sampled blocks from the neighbor sampler
(`repro.data.sampler`), which is how the reddit-scale cell trains.
"""
from __future__ import annotations

from typing import Any, Dict

import dataclasses
import jax
import jax.numpy as jnp

from ..common import dense_apply, dense_init
from .common import (
    GraphBatch,
    gather,
    mlp_init,
    mlp_apply,
    node_class_loss,
    graph_regression_loss,
    scatter_mean,
    segment_pool,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    d_in: int
    d_hidden: int = 128
    n_layers: int = 2
    n_classes: int = 41
    aggregator: str = "mean"
    graph_level: bool = False   # pool to per-graph output (molecule cells)


def sage_init(rng, cfg: SAGEConfig) -> Params:
    ks = jax.random.split(rng, cfg.n_layers * 2 + 1)
    p: Params = {}
    d = cfg.d_in
    for l in range(cfg.n_layers):
        out = cfg.d_hidden
        p[f"self{l}"] = dense_init(ks[2 * l], d, out)
        p[f"neigh{l}"] = dense_init(ks[2 * l + 1], d, out)
        d = out
    p["head"] = dense_init(ks[-1], d, cfg.n_classes)
    return p


def sage_apply(params: Params, cfg: SAGEConfig, gb: GraphBatch) -> jnp.ndarray:
    h = gb.x.astype(jnp.bfloat16)
    n = h.shape[0]
    for l in range(cfg.n_layers):
        msgs = gather(h, gb.edge_src)
        agg = scatter_mean(msgs, gb.edge_dst, gb.edge_mask, n)
        h = jax.nn.relu(
            dense_apply(params[f"self{l}"], h) +
            dense_apply(params[f"neigh{l}"], agg))
        norm = jnp.linalg.norm(h.astype(jnp.float32), axis=-1, keepdims=True)
        h = (h.astype(jnp.float32) / jnp.maximum(norm, 1e-6)).astype(h.dtype)
    if cfg.graph_level:
        pooled = segment_pool(h, gb.graph_ids, gb.node_mask, gb.n_graphs)
        return dense_apply(params["head"], pooled)
    return dense_apply(params["head"], h)


def sage_loss(params: Params, cfg: SAGEConfig, gb: GraphBatch) -> jnp.ndarray:
    out = sage_apply(params, cfg, gb)
    if cfg.graph_level:
        return graph_regression_loss(out[:, 0], gb.targets)
    return node_class_loss(out, gb.targets, gb.node_mask)
