"""GraphCast-style encoder-processor-decoder mesh GNN (arXiv:2212.12794).

Structure faithful to the paper: node/edge MLP encoders into d_hidden,
`n_layers` processor blocks of edge-update → sum-aggregate → node-update
(interaction networks with residuals), MLP decoder back to n_vars outputs.
The multi-mesh itself is an input graph (the launcher builds an icosahedral-
refinement-style synthetic mesh; the model is topology-agnostic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..common import dense_apply
from .common import (
    GraphBatch,
    gather,
    mlp_apply,
    mlp_init,
    node_regression_loss,
    scatter_sum,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    d_in: int
    d_hidden: int = 512
    n_layers: int = 16
    n_vars: int = 227
    mesh_refinement: int = 6


def graphcast_init(rng, cfg: GraphCastConfig) -> Params:
    ks = jax.random.split(rng, 3 + 2 * cfg.n_layers)
    H = cfg.d_hidden
    p: Params = {
        "enc_node": mlp_init(ks[0], (cfg.d_in, H, H)),
        "enc_edge": mlp_init(ks[1], (4, H, H)),  # edge feats: Δpos-ish 4-dim
    }
    for i in range(cfg.n_layers):
        p[f"proc{i}"] = {
            "edge": mlp_init(ks[2 + 2 * i], (3 * H, H, H)),
            "node": mlp_init(ks[3 + 2 * i], (2 * H, H, H)),
        }
    p["dec"] = mlp_init(ks[-1], (H, H, cfg.n_vars))
    return p


def graphcast_apply(params: Params, cfg: GraphCastConfig, gb: GraphBatch
                    ) -> jnp.ndarray:
    N = gb.x.shape[0]
    h = mlp_apply(params["enc_node"], gb.x.astype(jnp.bfloat16))
    # synthetic 4-d edge geometry features (normalized src/dst degree + const)
    ones = jnp.ones((gb.edge_src.shape[0], 1), jnp.bfloat16)
    deg = jnp.zeros((N,), jnp.bfloat16).at[gb.edge_dst].add(
        gb.edge_mask.astype(jnp.bfloat16))
    ef = jnp.concatenate(
        [ones,
         gather(deg, gb.edge_src)[:, None] / 16.0,
         gather(deg, gb.edge_dst)[:, None] / 16.0,
         gb.edge_mask.astype(jnp.bfloat16)[:, None]], axis=-1)
    e = mlp_apply(params["enc_edge"], ef)

    def processor(carry, lp):
        h, e = carry
        # edge update: e' = MLP(e ⊕ h_src ⊕ h_dst) + e
        eu = mlp_apply(lp["edge"], jnp.concatenate(
            [e, gather(h, gb.edge_src), gather(h, gb.edge_dst)], axis=-1))
        e = e + eu
        # node update: h' = MLP(h ⊕ Σ_in e') + h   (sum aggregator per config)
        agg = scatter_sum(e, gb.edge_dst, gb.edge_mask, N)
        hu = mlp_apply(lp["node"], jnp.concatenate([h, agg], axis=-1))
        return (h + hu, e), None

    # per-layer remat: full-batch cells (61M edges × d_hidden states) would
    # otherwise keep every layer's edge activations live through backward
    processor = jax.checkpoint(processor)
    for i in range(cfg.n_layers):
        (h, e), _ = processor((h, e), params[f"proc{i}"])

    return mlp_apply(params["dec"], h)


def graphcast_loss(params: Params, cfg: GraphCastConfig, gb: GraphBatch
                   ) -> jnp.ndarray:
    pred = graphcast_apply(params, cfg, gb)
    return node_regression_loss(pred, gb.targets, gb.node_mask)
