"""GNN substrate: static-shape graph batches + segment message passing.

JAX has no native sparse message passing — per the assignment this IS part
of the system: scatter/gather over an edge-index with ``segment_sum`` /
``.at[].add``, masked for padding, shardable over nodes (GSPMD inserts the
boundary exchange for cross-shard edges).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import dense_apply, dense_init
from ..sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded, static-shape (possibly batched) graph.

    x:         (N, F) node features.
    pos:       (N, 3) positions (geometric models) or None.
    edge_src:  (E,) int32 — message source.
    edge_dst:  (E,) int32 — message destination.
    edge_mask: (E,) bool — padding mask.
    node_mask: (N,) bool.
    graph_ids: (N,) int32 — which graph each node belongs to (batched mols).
    n_graphs:  static int.
    targets:   (N,) int labels / (N, V) regression / (G,) graph targets.
    """

    x: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    edge_mask: jnp.ndarray
    node_mask: jnp.ndarray
    graph_ids: jnp.ndarray
    n_graphs: int
    targets: jnp.ndarray
    pos: Optional[jnp.ndarray] = None


def _flatten_gb(gb: GraphBatch):
    dyn = (gb.x, gb.edge_src, gb.edge_dst, gb.edge_mask, gb.node_mask,
           gb.graph_ids, gb.targets, gb.pos)
    return dyn, gb.n_graphs


def _unflatten_gb(n_graphs, dyn):
    x, es, ed, em, nm, gi, tg, pos = dyn
    return GraphBatch(x=x, edge_src=es, edge_dst=ed, edge_mask=em, node_mask=nm,
                      graph_ids=gi, n_graphs=n_graphs, targets=tg, pos=pos)


jax.tree_util.register_pytree_node(GraphBatch, _flatten_gb, _unflatten_gb)


def scatter_sum(messages: jnp.ndarray, dst: jnp.ndarray, mask: jnp.ndarray,
                n_nodes: int) -> jnp.ndarray:
    """Masked scatter-add of (E, F) edge messages into (N, F) nodes."""
    msg = jnp.where(mask[:, None], messages, 0)
    out = jnp.zeros((n_nodes, messages.shape[-1]), messages.dtype).at[dst].add(msg)
    return constrain(out, "nodes", "hidden")


def scatter_mean(messages: jnp.ndarray, dst: jnp.ndarray, mask: jnp.ndarray,
                 n_nodes: int) -> jnp.ndarray:
    s = scatter_sum(messages, dst, mask, n_nodes)
    deg = jnp.zeros((n_nodes,), messages.dtype).at[dst].add(
        mask.astype(messages.dtype))
    return s / jnp.maximum(deg, 1)[:, None]


def scatter_max(messages: jnp.ndarray, dst: jnp.ndarray, mask: jnp.ndarray,
                n_nodes: int) -> jnp.ndarray:
    neg = jnp.asarray(-1e30, messages.dtype)
    msg = jnp.where(mask[:, None], messages, neg)
    out = jnp.full((n_nodes, messages.shape[-1]), neg, messages.dtype).at[dst].max(msg)
    return jnp.where(out <= neg / 2, 0, out)


def gather(nodes: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(nodes, idx, axis=0)


def segment_pool(node_feat: jnp.ndarray, graph_ids: jnp.ndarray,
                 node_mask: jnp.ndarray, n_graphs: int, *, mean: bool = True):
    """Per-graph pooling for batched small graphs."""
    feat = jnp.where(node_mask[:, None], node_feat, 0)
    s = jnp.zeros((n_graphs, node_feat.shape[-1]), node_feat.dtype).at[graph_ids].add(feat)
    if not mean:
        return s
    cnt = jnp.zeros((n_graphs,), node_feat.dtype).at[graph_ids].add(
        node_mask.astype(node_feat.dtype))
    return s / jnp.maximum(cnt, 1)[:, None]


# ---------------------------------------------------------------------------
# small MLP helper
# ---------------------------------------------------------------------------

def mlp_init(rng, dims: Sequence[int]) -> Params:
    ks = jax.random.split(rng, len(dims) - 1)
    return {f"l{i}": dense_init(ks[i], dims[i], dims[i + 1])
            for i in range(len(dims) - 1)}


def mlp_apply(params: Params, x: jnp.ndarray, *, act=jax.nn.silu,
              final_act: bool = False, dtype=jnp.bfloat16) -> jnp.ndarray:
    n = len(params)
    for i in range(n):
        x = dense_apply(params[f"l{i}"], x, dtype=dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# Losses shared by GNN tasks
# ---------------------------------------------------------------------------

def node_class_loss(logits: jnp.ndarray, targets: jnp.ndarray,
                    node_mask: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    per = (logz - gold) * node_mask
    return per.sum() / jnp.maximum(node_mask.sum(), 1)


def node_regression_loss(pred: jnp.ndarray, targets: jnp.ndarray,
                         node_mask: jnp.ndarray) -> jnp.ndarray:
    targets = targets.astype(jnp.float32)
    if targets.ndim == pred.ndim - 1:
        targets = jnp.broadcast_to(targets[..., None], pred.shape)
    err = jnp.square(pred.astype(jnp.float32) - targets)
    err = err.mean(axis=-1) * node_mask
    return err.sum() / jnp.maximum(node_mask.sum(), 1)


def graph_regression_loss(pred: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.square(pred.astype(jnp.float32) -
                               targets.astype(jnp.float32)))
