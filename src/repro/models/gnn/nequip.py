"""NequIP-family E(3)-equivariant interatomic potential (arXiv:2101.03164).

Hardware adaptation (DESIGN.md §2/§5): e3nn's spherical-harmonic irrep
machinery is replaced by the equivalent *Cartesian* irreps up to l_max = 2 —
node state = (scalars s, vectors v, traceless-symmetric rank-2 tensors t),
messages combine neighbor features with edge harmonics Y0 = 1, Y1 = û,
Y2 = ûûᵀ − I/3 through every symmetry-allowed product path, each path gated
by a radial-MLP weight (Bessel basis, polynomial cutoff).  All ops are
covariant by construction, so E(3)-equivariance holds exactly (property-
tested under random rotations in tests/models/test_gnn.py) while everything
lowers to dense einsums + segment_sum — the TPU-friendly form of the
tensor-product kernel regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import dense_apply, dense_init
from .common import (
    GraphBatch,
    gather,
    graph_regression_loss,
    mlp_apply,
    mlp_init,
    scatter_sum,
    segment_pool,
)

Params = Dict[str, Any]

# symmetry-allowed message paths (in_l, sh_l, out_l), Cartesian form
_PATHS = [
    ("s", 0, "s"), ("s", 1, "v"), ("s", 2, "t"),
    ("v", 0, "v"), ("v", 1, "s"), ("v", 1, "t"), ("v", 2, "v"),
    ("t", 0, "t"), ("t", 1, "v"), ("t", 2, "s"),
]


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    d_in: int
    d_hidden: int = 32          # channels per irrep order
    n_layers: int = 5
    n_rbf: int = 8
    cutoff: float = 5.0
    graph_level: bool = True


def bessel_basis(d: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """(E,) → (E, n_rbf) Bessel radial basis with polynomial cutoff."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d[:, None] / cutoff) / d[:, None]
    u = jnp.clip(d / cutoff, 0, 1)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5  # p=3 polynomial cutoff
    return basis * env[:, None]


def _traceless(t: jnp.ndarray) -> jnp.ndarray:
    tr = jnp.trace(t, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=t.dtype)
    return 0.5 * (t + jnp.swapaxes(t, -1, -2)) - tr * eye / 3.0


def nequip_init(rng, cfg: NequIPConfig) -> Params:
    ks = jax.random.split(rng, 2 + cfg.n_layers)
    C = cfg.d_hidden
    p: Params = {"embed": dense_init(ks[0], cfg.d_in, C)}
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[1 + i], 6)
        p[f"layer{i}"] = {
            # radial MLP: one weight per (path, channel)
            "radial": mlp_init(kk[0], (cfg.n_rbf, 32, len(_PATHS) * C)),
            "self_s": dense_init(kk[1], C, C),
            "self_v": dense_init(kk[2], C, C),
            "self_t": dense_init(kk[3], C, C),
            "gate_v": dense_init(kk[4], C, C),
            "gate_t": dense_init(kk[5], C, C),
        }
    p["head"] = mlp_init(ks[-1], (C, C, 1))
    return p


def _messages(s_j, v_j, t_j, y1, y2, w) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-edge tensor-product messages. Shapes:
    s_j (E,C); v_j (E,C,3); t_j (E,C,3,3); y1 (E,3); y2 (E,3,3); w (E,P,C)."""
    u1 = y1[:, None, :]                       # (E,1,3)
    u2 = y2[:, None, :, :]                    # (E,1,3,3)
    pi = {name: idx for idx, name in enumerate(
        [f"{a}{l}{b}" for a, l, b in _PATHS])}

    def W(a, l, b):
        return w[:, pi[f"{a}{l}{b}"], :]

    m_s = (W("s", 0, "s") * s_j
           + W("v", 1, "s") * jnp.einsum("ecx,ex->ec", v_j, y1)
           + W("t", 2, "s") * jnp.einsum("ecxy,exy->ec", t_j, y2))
    m_v = (W("s", 1, "v")[..., None] * (s_j[..., None] * u1)
           + W("v", 0, "v")[..., None] * v_j
           + W("v", 2, "v")[..., None] * jnp.einsum("ecx,exy->ecy", v_j, y2)
           + W("t", 1, "v")[..., None] * jnp.einsum("ecxy,ey->ecx", t_j, y1))
    m_t = (W("s", 2, "t")[..., None, None] * (s_j[..., None, None] * u2)
           + W("v", 1, "t")[..., None, None] * _traceless(
               jnp.einsum("ecx,ey->ecxy", v_j, y1))
           + W("t", 0, "t")[..., None, None] * t_j)
    return m_s, m_v, m_t


def nequip_apply(params: Params, cfg: NequIPConfig, gb: GraphBatch) -> jnp.ndarray:
    assert gb.pos is not None, "NequIP needs positions"
    N = gb.x.shape[0]
    C = cfg.d_hidden
    f32 = jnp.float32
    s = dense_apply(params["embed"], gb.x.astype(f32), dtype=f32)     # (N,C)
    v = jnp.zeros((N, C, 3), f32)
    t = jnp.zeros((N, C, 3, 3), f32)

    rij = (gather(gb.pos, gb.edge_src) - gather(gb.pos, gb.edge_dst)).astype(f32)
    dist = jnp.linalg.norm(rij + 1e-12, axis=-1)
    y1 = rij / jnp.maximum(dist, 1e-6)[:, None]                        # (E,3)
    y2 = _traceless(jnp.einsum("ex,ey->exy", y1, y1))                  # (E,3,3)
    rbf = bessel_basis(dist, cfg.n_rbf, cfg.cutoff)

    for i in range(cfg.n_layers):
        lp = params[f"layer{i}"]
        w = _radial(lp["radial"], rbf).reshape(-1, len(_PATHS), C)
        s_j, v_j, t_j = gather(s, gb.edge_src), gather(v, gb.edge_src), gather(t, gb.edge_src)
        m_s, m_v, m_t = _messages(s_j, v_j, t_j, y1, y2, w)
        em = gb.edge_mask
        agg_s = scatter_sum(m_s, gb.edge_dst, em, N)
        agg_v = scatter_sum(m_v.reshape(-1, C * 3), gb.edge_dst, em, N).reshape(N, C, 3)
        agg_t = scatter_sum(m_t.reshape(-1, C * 9), gb.edge_dst, em, N).reshape(N, C, 3, 3)
        # self-interaction: channel mixing per irrep (equivariant — acts on C)
        s = s + jnp.tanh(dense_apply(lp["self_s"], agg_s, dtype=f32))
        gate_v = jax.nn.sigmoid(dense_apply(lp["gate_v"], s, dtype=f32))
        gate_t = jax.nn.sigmoid(dense_apply(lp["gate_t"], s, dtype=f32))
        v = v + gate_v[..., None] * jnp.einsum(
            "ncx,cd->ndx", agg_v, lp["self_v"]["kernel"].astype(f32))
        t = t + gate_t[..., None, None] * jnp.einsum(
            "ncxy,cd->ndxy", agg_t, lp["self_t"]["kernel"].astype(f32))

    energy = mlp_apply(params["head"], s, act=jax.nn.silu, dtype=f32)  # (N,1)
    if cfg.graph_level:
        return segment_pool(energy, gb.graph_ids, gb.node_mask, gb.n_graphs,
                            mean=False)
    return energy


def _radial(mlp_params: Params, rbf: jnp.ndarray) -> jnp.ndarray:
    return mlp_apply(mlp_params, rbf.astype(jnp.float32), dtype=jnp.float32)


def nequip_loss(params: Params, cfg: NequIPConfig, gb: GraphBatch) -> jnp.ndarray:
    out = nequip_apply(params, cfg, gb)
    if cfg.graph_level:
        return graph_regression_loss(out[:, 0], gb.targets)
    from .common import node_regression_loss

    return node_regression_loss(out, gb.targets, gb.node_mask)
