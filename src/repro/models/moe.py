"""Mixture-of-Experts FFN — per-row sort-based dispatch, expert-parallel.

TPU/SPMD-native formulation: every *batch row* dispatches its own S×top_k
assignments (argsort by expert id, per-expert capacity, overflow dropped),
so the dispatch tensors stay sharded over the data axis — no global sort,
no cross-shard scatter.  Expert weights shard over the model axis (EP when
`n_experts` divides it; the launcher degrades to within-expert TP on the
FFN dim otherwise, e.g. mixtral's 8 experts on 16 chips), and the combine
is a local gather + scatter-add whose cross-expert reduction lowers to one
all-reduce over the model axis.

Dispatch is *gather-based*: a small int32 `tok_of_slot` (B, E, C) table is
scattered once, then activations are only ever gathered — cheap on TPU and
friendly to GSPMD propagation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init
from .sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize top-k gates (Mixtral-style)


def capacity(cfg: MoEConfig, tokens_per_row: int) -> int:
    c = int(np.ceil(tokens_per_row * cfg.top_k * cfg.capacity_factor
                    / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # multiple of 8 for lane alignment


def moe_init(rng, cfg: MoEConfig) -> Params:
    ks = jax.random.split(rng, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    return {
        "router": dense_init(ks[0], D, E),
        "wi": s_in * jax.random.normal(ks[1], (E, D, F), jnp.float32),
        "wg": s_in * jax.random.normal(ks[2], (E, D, F), jnp.float32),
        "wo": s_out * jax.random.normal(ks[3], (E, F, D), jnp.float32),
    }


def moe_apply(params: Params, cfg: MoEConfig, x: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (y, aux_loss).  SwiGLU experts."""
    B, S, D = x.shape
    K, E = cfg.top_k, cfg.n_experts
    C = capacity(cfg, S)
    SK = S * K
    dt = x.dtype

    logits = (x.astype(jnp.float32) @ params["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    gate, idx = jax.lax.top_k(probs, K)                          # (B, S, K)
    if cfg.router_norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- per-row dispatch plan (all ops stay sharded over batch) ----------
    fe = idx.reshape(B, SK).astype(jnp.int32)
    fg = gate.reshape(B, SK)
    ftok = jnp.broadcast_to(
        (jnp.arange(SK, dtype=jnp.int32) // K)[None], (B, SK))
    order = jnp.argsort(fe, axis=1, stable=True)
    se = jnp.take_along_axis(fe, order, axis=1)
    st = jnp.take_along_axis(ftok, order, axis=1)
    sg = jnp.take_along_axis(fg, order, axis=1)
    brow = jnp.arange(B, dtype=jnp.int32)[:, None]
    counts = jnp.zeros((B, E), jnp.int32).at[brow, fe].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts
    rank = jnp.arange(SK, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(starts, se, axis=1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)                 # drop col

    # slot → (token, gate) tables; sentinel token = S marks empty slots
    tok_of_slot = jnp.full((B, E * C + 1), S, jnp.int32
                           ).at[brow, slot].set(st)[:, : E * C]
    gate_of_slot = jnp.zeros((B, E * C + 1), fg.dtype
                             ).at[brow, slot].set(sg)[:, : E * C]
    filled = (tok_of_slot < S)[..., None]                        # (B, E·C, 1)

    # ---- gather-dispatch → expert FFN → weighted combine -------------------
    xe = jnp.take_along_axis(
        x, jnp.minimum(tok_of_slot, S - 1)[..., None], axis=1)
    xe = jnp.where(filled, xe, 0).reshape(B, E, C, D)
    xe = constrain(xe, "batch", "expert", None, None)

    wi = params["wi"].astype(dt)
    wg = params["wg"].astype(dt)
    wo = params["wo"].astype(dt)
    h = jnp.einsum("becd,edf->becf", xe, wi)
    g = jnp.einsum("becd,edf->becf", xe, wg)
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, wo)
    ye = constrain(ye, "batch", "expert", None, None)

    contrib = (ye.reshape(B, E * C, D)
               * gate_of_slot[..., None].astype(dt)
               * filled.astype(dt))
    y = jnp.zeros((B, S + 1, D), dt).at[
        brow[..., None], tok_of_slot[..., None],
        jnp.arange(D)[None, None]].add(contrib)[:, :S]
    y = constrain(y, "batch", "residual", "embed")

    # Switch-style load-balancing aux loss
    me = probs.mean(axis=(0, 1))                                 # (E,)
    fe_frac = counts.sum(0).astype(jnp.float32) / (B * SK)
    aux = E * jnp.sum(me * fe_frac)
    return y, aux
