"""DLRM-RM2 (Naumov et al., arXiv:1906.00091) — dot-interaction recsys model.

13 dense features → bottom MLP; 26 sparse features → row-sharded
EmbeddingBags; pairwise dot interaction over the 27 embedding-dim vectors;
top MLP → CTR logit.  Extra entry point `retrieval_score` serves the
1 × 10⁶-candidate retrieval cell as one batched matmul (no loops).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_apply
from .embedding import embedding_bag_apply, embedding_bag_init
from .gnn.common import mlp_apply, mlp_init
from .sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    table_rows: int = 1_000_000
    n_hot: int = 1

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    @property
    def top_in(self) -> int:
        return self.n_interact + self.embed_dim


def dlrm_init(rng, cfg: DLRMConfig) -> Params:
    ks = jax.random.split(rng, cfg.n_sparse + 2)
    return {
        "bot": mlp_init(ks[0], (cfg.n_dense,) + cfg.bot_mlp),
        "tables": {
            f"t{i}": embedding_bag_init(ks[1 + i], cfg.table_rows, cfg.embed_dim)
            for i in range(cfg.n_sparse)
        },
        "top": mlp_init(ks[-1], (cfg.top_in,) + cfg.top_mlp),
    }


def _interact(vecs: jnp.ndarray) -> jnp.ndarray:
    """(B, F, D) → (B, F(F−1)/2) upper-triangle pairwise dots."""
    B, F, D = vecs.shape
    z = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    iu, ju = np.triu_indices(F, k=1)
    return z[:, iu, ju]


def dlrm_apply(params: Params, cfg: DLRMConfig, dense: jnp.ndarray,
               sparse_idx: jnp.ndarray) -> jnp.ndarray:
    """dense: (B, 13) float; sparse_idx: (B, 26, n_hot) int32 → (B,) logits."""
    B = dense.shape[0]
    dense = constrain(dense.astype(jnp.bfloat16), "batch", None)
    bot = mlp_apply(params["bot"], dense, act=jax.nn.relu, final_act=True)
    embs = [
        embedding_bag_apply(params["tables"][f"t{i}"], sparse_idx[:, i])
        for i in range(cfg.n_sparse)
    ]
    vecs = jnp.stack([bot] + embs, axis=1)          # (B, 27, D)
    vecs = constrain(vecs, "batch", None, "feature")
    feat = jnp.concatenate([_interact(vecs), bot], axis=-1)
    logit = mlp_apply(params["top"], feat, act=jax.nn.relu)
    return logit[:, 0]


def dlrm_loss(params: Params, cfg: DLRMConfig, dense, sparse_idx, labels
              ) -> jnp.ndarray:
    logits = dlrm_apply(params, cfg, dense, sparse_idx).astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels +
        jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_score(params: Params, cfg: DLRMConfig, dense: jnp.ndarray,
                    sparse_idx: jnp.ndarray, candidates: jnp.ndarray,
                    *, top_k: int = 100) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Score one query against (C, D) candidate embeddings: batched dot,
    not a loop.  Returns (scores, ids) of the top_k."""
    bot = mlp_apply(params["bot"], dense.astype(jnp.bfloat16),
                    act=jax.nn.relu, final_act=True)     # (B, D)
    embs = [
        embedding_bag_apply(params["tables"][f"t{i}"], sparse_idx[:, i])
        for i in range(cfg.n_sparse)
    ]
    query = bot + sum(embs)                               # (B, D) fused user tower
    scores = jnp.einsum("bd,cd->bc", query,
                        candidates.astype(query.dtype)).astype(jnp.float32)
    return jax.lax.top_k(scores, top_k)
