"""Shared model building blocks — pure-functional, pytree params.

Every layer is (init(rng, ...) -> params, apply(params, x, ...) -> y).
Params are fp32; compute is bf16 by default (cast at the boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import constrain

__all__ = [
    "Initializer", "dense_init", "dense_apply", "rmsnorm_init", "rmsnorm_apply",
    "embed_init", "embed_apply", "rotary_embedding", "apply_rope",
    "softcap", "count_params", "param_bytes", "cast_tree",
]

Params = Dict[str, Any]


def _normal(rng, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(rng, shape, dtype)


def dense_init(rng, in_dim: int, out_dim: int, *, scale: Optional[float] = None
               ) -> Params:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return {"kernel": _normal(rng, (in_dim, out_dim), scale)}


def dense_apply(params: Params, x: jnp.ndarray, *, dtype=jnp.bfloat16) -> jnp.ndarray:
    return x.astype(dtype) @ params["kernel"].astype(dtype)


def rmsnorm_init(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm_apply(params: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"])).astype(dt)


def embed_init(rng, vocab: int, dim: int) -> Params:
    return {"table": _normal(rng, (vocab, dim), 1.0)}


def embed_apply(params: Params, ids: jnp.ndarray, *, dtype=jnp.bfloat16) -> jnp.ndarray:
    out = jnp.take(params["table"].astype(dtype), ids, axis=0)
    return constrain(out, "batch", "seq", "embed")


def rotary_embedding(positions: jnp.ndarray, head_dim: int,
                     base: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(…,) positions → cos/sin tables of shape (…, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params) -> int:
    return sum(p.size * p.dtype.itemsize for p in jax.tree_util.tree_leaves(params))


def cast_tree(params, dtype):
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params)
