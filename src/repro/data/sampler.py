"""Neighbor sampler — real fanout sampling for the minibatch_lg cell.

GraphSAGE-style layered uniform sampling from CSR on the host (numpy),
emitting *static-shape padded blocks* the device step consumes: seeds →
fanout[0] neighbors → fanout[1] neighbors, with local re-indexing, padding
masks, and per-seed targets.  Deterministic per (seed, step) so the
pipeline is checkpoint-resumable.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import DataGraph

__all__ = ["SampledBlock", "NeighborSampler"]


@dataclasses.dataclass
class SampledBlock:
    """Padded sampled subgraph (see GNNArch minibatch_lg input spec)."""

    node_ids: np.ndarray    # (N_pad,) global ids (-1 pad)
    x_rows: np.ndarray      # (N_pad,) row into the feature matrix (0 for pad)
    edge_src: np.ndarray    # (E_pad,) local indices
    edge_dst: np.ndarray    # (E_pad,)
    edge_mask: np.ndarray   # (E_pad,) bool
    node_mask: np.ndarray   # (N_pad,) bool — True for seeds (loss nodes)
    n_real_nodes: int
    n_real_edges: int


class NeighborSampler:
    def __init__(self, graph: DataGraph, *, fanout: Sequence[int] = (15, 10),
                 batch_nodes: int = 1024, seed: int = 0):
        self.g = graph
        self.fanout = tuple(fanout)
        self.batch = batch_nodes
        self.seed = seed
        # static pad sizes (must match the arch's input spec derivation)
        n_cap = batch_nodes
        e_cap = 0
        layer = batch_nodes
        for f in self.fanout:
            e_cap += layer * f
            layer *= f
            n_cap += layer
        self.node_cap = n_cap
        self.edge_cap = e_cap

    def _sample_neighbors(self, rng, frontier: np.ndarray, fanout: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """For each vertex, up to `fanout` uniform out-neighbors (without
        replacement when degree ≥ fanout)."""
        srcs, dsts = [], []
        for v in frontier:
            nbrs = self.g.neighbors_out(int(v))
            if nbrs.size == 0:
                continue
            if nbrs.size > fanout:
                picked = rng.choice(nbrs, size=fanout, replace=False)
            else:
                picked = nbrs
            srcs.append(np.full(picked.size, v, np.int64))
            dsts.append(picked.astype(np.int64))
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(srcs), np.concatenate(dsts)

    def sample(self, step: int) -> SampledBlock:
        rng = np.random.default_rng((self.seed, step))
        seeds = rng.choice(self.g.n, size=min(self.batch, self.g.n),
                           replace=False)
        nodes = list(seeds)
        index = {int(v): i for i, v in enumerate(seeds)}
        es, ed = [], []
        frontier = seeds
        for f in self.fanout:
            s, d = self._sample_neighbors(rng, frontier, f)
            new_frontier = []
            for sv, dv in zip(s, d):
                dv = int(dv)
                if dv not in index:
                    index[dv] = len(nodes)
                    nodes.append(dv)
                    new_frontier.append(dv)
                # message flows neighbor → seed side (dst aggregates src)
                es.append(index[dv])
                ed.append(index[int(sv)])
            frontier = np.array(new_frontier, np.int64) if new_frontier \
                else np.zeros(0, np.int64)

        n_real, e_real = len(nodes), len(es)
        assert n_real <= self.node_cap and e_real <= self.edge_cap
        node_ids = np.full(self.node_cap, -1, np.int64)
        node_ids[:n_real] = nodes
        x_rows = np.maximum(node_ids, 0)
        edge_src = np.zeros(self.edge_cap, np.int32)
        edge_dst = np.zeros(self.edge_cap, np.int32)
        edge_mask = np.zeros(self.edge_cap, bool)
        edge_src[:e_real] = es
        edge_dst[:e_real] = ed
        edge_mask[:e_real] = True
        node_mask = np.zeros(self.node_cap, bool)
        node_mask[: seeds.size] = True  # loss on seed nodes only
        return SampledBlock(node_ids=node_ids, x_rows=x_rows,
                            edge_src=edge_src, edge_dst=edge_dst,
                            edge_mask=edge_mask, node_mask=node_mask,
                            n_real_nodes=n_real, n_real_edges=e_real)

    def blocks(self, *, start_step: int = 0) -> Iterator[SampledBlock]:
        step = start_step
        while True:
            yield self.sample(step)
            step += 1
