"""Synthetic data generators — FSM graphs, LM tokens, DLRM batches.

The paper's datasets are SNAP graphs with *randomly assigned* labels (§4).
Offline we synthesize structure-matched stand-ins: R-MAT graphs with the
same |V|, |E|, |V_l| and random labels — label selectivity and degree skew
(the two workload-shaping statistics) are faithful by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..core.graph import DataGraph, build_graph

__all__ = ["rmat_graph", "paper_dataset", "PAPER_DATASETS", "token_stream",
           "dlrm_batches"]


def rmat_graph(n: int, m: int, *, n_labels: int = 5, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               undirected: bool = False) -> DataGraph:
    """R-MAT (Chakrabarti et al.) directed labeled graph, power-law degrees."""
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(max(n, 2)))))
    n_pow = 1 << scale
    # oversample to survive self-loop/dup removal
    m_gen = int(m * 1.3) + 16
    src = np.zeros(m_gen, dtype=np.int64)
    dst = np.zeros(m_gen, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m_gen)
        quad_b = (r >= a) & (r < a + b)
        quad_c = (r >= a + b) & (r < a + b + c)
        quad_d = r >= a + b + c
        bit = 1 << level
        src += bit * (quad_c | quad_d)
        dst += bit * (quad_b | quad_d)
    keep = (src < n) & (dst < n) & (src != dst)
    src, dst = src[keep], dst[keep]
    keys = np.unique(src * n + dst)[:m]
    src, dst = keys // n, keys % n
    labels = rng.integers(0, n_labels, n).astype(np.int32)
    edges = np.stack([src, dst], axis=1)
    return build_graph(n, edges, labels, n_labels=n_labels,
                       undirected=undirected)


# Paper Table 1, scaled stand-ins (scale=1.0 reproduces the table sizes).
PAPER_DATASETS: Dict[str, Dict] = {
    "gnutella": dict(n=6301, m=20777, n_labels=5),
    "epinions": dict(n=75879, m=508837, n_labels=5),
    "slashdot": dict(n=82168, m=948464, n_labels=5),
    "wiki-vote": dict(n=7115, m=103689, n_labels=5),
    "mico": dict(n=100000, m=1080298, n_labels=29),
}


def paper_dataset(name: str, *, scale: float = 1.0, seed: int = 0) -> DataGraph:
    cfg = PAPER_DATASETS[name]
    n = max(16, int(cfg["n"] * scale))
    m = max(32, int(cfg["m"] * scale))
    return rmat_graph(n, m, n_labels=cfg["n_labels"], seed=seed,
                      undirected=True)


# ---------------------------------------------------------------------------
# LM token pipeline (synthetic Zipfian text) — deterministic + resumable
# ---------------------------------------------------------------------------

def token_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
                 start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens, targets) with Zipf-ish marginals; step-indexed rng so
    a restore at step k reproduces the exact stream (checkpoint manifest
    stores the cursor)."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]
        step += 1


def dlrm_batches(cfg, batch: int, *, seed: int = 0, start_step: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        yield {
            "dense": rng.normal(size=(batch, cfg.n_dense)).astype(np.float32),
            "sparse_idx": rng.integers(
                0, cfg.table_rows, (batch, cfg.n_sparse, cfg.n_hot)
            ).astype(np.int32),
            "labels": rng.integers(0, 2, (batch,)).astype(np.int32),
        }
        step += 1
