"""Resumable elastic mining runtime.

Checkpointed mining sessions over `repro.core.flexis.mine`: atomic
snapshots of the full mining state (pattern frontier + host bookkeeping +
in-flight device metric state) at level-boundary and root-block /
super-block granularity, with mesh-shape-agnostic restore.  See
`docs/architecture.md` ("Sessions and resume") for the dataflow.
"""
from . import faults
from .faults import FaultPlan, FaultSpec, InjectedCrash, InjectedFault
from .session import DEFAULT_BLOCKS_PER_SUPER, MiningSession, PreemptedError
from .state import (
    GroupDone,
    LevelCursor,
    SampledCursor,
    SessionState,
    decode_session,
    encode_session,
)
from .resume import (
    SessionMismatch,
    latest_snapshot,
    load_session,
    session_fingerprint,
)

__all__ = [
    "MiningSession", "PreemptedError", "DEFAULT_BLOCKS_PER_SUPER",
    "faults", "FaultPlan", "FaultSpec", "InjectedCrash", "InjectedFault",
    "SessionState", "LevelCursor", "GroupDone", "SampledCursor",
    "encode_session", "decode_session",
    "load_session", "latest_snapshot", "session_fingerprint",
    "SessionMismatch",
]
