"""Snapshot loading + validation for mining sessions.

Split from `session.py` so a restore can be driven standalone (inspect a
checkpoint directory, validate it against a config, rebuild the
`SessionState`) without constructing a `MiningSession`.

Mesh-shape-agnostic restore: the snapshot's array leaves were written as
full logical arrays (`train/checkpoint.py` guarantees this), and shapes
are read back from the checkpoint *manifest* — not from a caller-supplied
template — so the loader needs no advance knowledge of bucket sizes or
pattern counts.  Device placement happens lazily: the mining loop hands
the restored host arrays straight back to jit/`shard_map`
(`jnp.asarray` / implicit `device_put` under the current mesh), which is
where a 4-device snapshot becomes an 8- or 1-device resident without any
format change.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.flexis import MiningConfig
from repro.core.graph import DataGraph
from repro.train import checkpoint as ckpt

from .state import SessionState, decode_session

__all__ = ["session_fingerprint", "latest_snapshot", "load_session",
           "SessionMismatch"]


class SessionMismatch(ValueError):
    """A snapshot exists but was written by an incompatible run."""


def session_fingerprint(g: DataGraph, cfg: MiningConfig) -> Dict[str, Any]:
    """Identity of a mining run: the graph (structure + labels) and every
    result-relevant config knob.  Wall-clock budget (``time_limit_s``) is
    deliberately excluded — a *killed* run may legitimately be resumed
    under a bigger budget without changing any mined value.  (A run that
    ran to its timeout is *finished*: per the paper's timeout semantics it
    reports the truncated result, its final snapshot carries an empty
    candidate list, and resuming it re-materializes that result rather
    than mining further.)"""
    cfg_d = dataclasses.asdict(cfg)
    cfg_d.pop("time_limit_s", None)
    return {
        "graph": {
            "n": int(g.n),
            "n_edges": int(g.n_edges),
            "n_labels": int(g.n_labels),
            "labels_crc": zlib.crc32(np.ascontiguousarray(g.labels)),
            "edges_crc": zlib.crc32(np.ascontiguousarray(g.edge_keys)),
        },
        "config": cfg_d,
    }


def latest_snapshot(checkpoint_dir) -> Optional[int]:
    """Step index of the newest committed session snapshot, or None."""
    return ckpt.latest_step(Path(checkpoint_dir))


def _manifest(checkpoint_dir: Path, step: int) -> Dict[str, Any]:
    d = Path(checkpoint_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())


def _load_step(checkpoint_dir: Path, step: int, cfg: MiningConfig,
               fingerprint: Optional[Dict[str, Any]]
               ) -> Tuple[SessionState, int]:
    """Load + validate one committed step (raises on any defect)."""
    manifest = _manifest(checkpoint_dir, step)
    # rebuild the leaf template from the manifest itself: logical shapes
    # are authoritative there, which is what makes the restore mesh-free
    template = [
        jax.ShapeDtypeStruct(tuple(leaf["shape"]), np.dtype(leaf["dtype"]))
        for leaf in manifest["leaves"]
    ]
    leaves, extra, step = ckpt.restore(checkpoint_dir, template, step=step)
    stored = extra.get("fingerprint")
    if fingerprint is not None and stored != fingerprint:
        raise SessionMismatch(
            f"snapshot under {checkpoint_dir} was written by a different "
            f"run:\n  stored:  {stored}\n  current: {fingerprint}")
    leaves = [np.asarray(leaf) for leaf in leaves]
    return decode_session(leaves, extra, cfg.metric), step


def load_session(checkpoint_dir, cfg: MiningConfig, *,
                 step: Optional[int] = None,
                 fingerprint: Optional[Dict[str, Any]] = None,
                 health=None,
                 ) -> Optional[Tuple[SessionState, int]]:
    """Load (SessionState, step) from the newest *healthy* snapshot.

    Self-healing restore: when the newest committed step turns out corrupt
    — unreadable/garbage manifest, missing array file, CRC mismatch
    (`checkpoint.CorruptCheckpointError`), undecodable session state — the
    loader falls back across the retained COMMIT chain, newest→oldest,
    instead of raising.  Every skipped step is recorded on ``health`` (a
    `repro.core.health.RunHealth`) as a ``restore_fallback`` event.  The
    worst case (every retained step corrupt) returns None, i.e. a fresh
    run — degraded but never wrong.

    An explicit ``step`` is strict: the caller asked for that exact
    snapshot, so its defects propagate.  A `SessionMismatch` is never
    fallen past either — resuming someone else's checkpoint silently would
    *look* like a successful resume and mine garbage; an older step of the
    same directory would mismatch identically.

    Returns None when the directory holds no (healthy) committed snapshot.
    """
    checkpoint_dir = Path(checkpoint_dir)
    if step is not None:
        return _load_step(checkpoint_dir, step, cfg, fingerprint)
    steps = ckpt.committed_steps(checkpoint_dir)
    for s in reversed(steps):
        try:
            return _load_step(checkpoint_dir, s, cfg, fingerprint)
        except SessionMismatch:
            raise
        except (OSError, ValueError, KeyError, TypeError) as e:
            # CorruptCheckpointError is a ValueError; FileNotFoundError
            # (missing array/manifest) is an OSError; decode_session format
            # defects surface as ValueError/KeyError/TypeError
            if health is not None:
                if (isinstance(e, ckpt.CorruptCheckpointError)
                        and "CRC mismatch" in str(e)):
                    health.record("checksum_mismatch", str(e), step=s)
                health.record(
                    "restore_fallback",
                    f"step {s} corrupt ({type(e).__name__}: {e}); "
                    f"falling back", step=s)
            continue
    return None
