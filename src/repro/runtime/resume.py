"""Snapshot loading + validation for mining sessions.

Split from `session.py` so a restore can be driven standalone (inspect a
checkpoint directory, validate it against a config, rebuild the
`SessionState`) without constructing a `MiningSession`.

Mesh-shape-agnostic restore: the snapshot's array leaves were written as
full logical arrays (`train/checkpoint.py` guarantees this), and shapes
are read back from the checkpoint *manifest* — not from a caller-supplied
template — so the loader needs no advance knowledge of bucket sizes or
pattern counts.  Device placement happens lazily: the mining loop hands
the restored host arrays straight back to jit/`shard_map`
(`jnp.asarray` / implicit `device_put` under the current mesh), which is
where a 4-device snapshot becomes an 8- or 1-device resident without any
format change.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.flexis import MiningConfig
from repro.core.graph import DataGraph
from repro.train import checkpoint as ckpt

from .state import SessionState, decode_session

__all__ = ["session_fingerprint", "latest_snapshot", "load_session",
           "SessionMismatch"]


class SessionMismatch(ValueError):
    """A snapshot exists but was written by an incompatible run."""


def session_fingerprint(g: DataGraph, cfg: MiningConfig) -> Dict[str, Any]:
    """Identity of a mining run: the graph (structure + labels) and every
    result-relevant config knob.  Wall-clock budget (``time_limit_s``) is
    deliberately excluded — a *killed* run may legitimately be resumed
    under a bigger budget without changing any mined value.  (A run that
    ran to its timeout is *finished*: per the paper's timeout semantics it
    reports the truncated result, its final snapshot carries an empty
    candidate list, and resuming it re-materializes that result rather
    than mining further.)"""
    cfg_d = dataclasses.asdict(cfg)
    cfg_d.pop("time_limit_s", None)
    return {
        "graph": {
            "n": int(g.n),
            "n_edges": int(g.n_edges),
            "n_labels": int(g.n_labels),
            "labels_crc": zlib.crc32(np.ascontiguousarray(g.labels)),
            "edges_crc": zlib.crc32(np.ascontiguousarray(g.edge_keys)),
        },
        "config": cfg_d,
    }


def latest_snapshot(checkpoint_dir) -> Optional[int]:
    """Step index of the newest committed session snapshot, or None."""
    return ckpt.latest_step(Path(checkpoint_dir))


def _manifest(checkpoint_dir: Path, step: int) -> Dict[str, Any]:
    d = Path(checkpoint_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())


def load_session(checkpoint_dir, cfg: MiningConfig, *,
                 step: Optional[int] = None,
                 fingerprint: Optional[Dict[str, Any]] = None,
                 ) -> Optional[Tuple[SessionState, int]]:
    """Load (SessionState, step) from the newest committed snapshot.

    Returns None when the directory holds no committed snapshot.  When
    ``fingerprint`` is given (see `session_fingerprint`), a stored
    snapshot whose identity differs raises `SessionMismatch` — resuming
    someone else's checkpoint silently would *look* like a successful
    resume and mine garbage.
    """
    checkpoint_dir = Path(checkpoint_dir)
    if step is None:
        step = latest_snapshot(checkpoint_dir)
        if step is None:
            return None
    manifest = _manifest(checkpoint_dir, step)
    # rebuild the leaf template from the manifest itself: logical shapes
    # are authoritative there, which is what makes the restore mesh-free
    template = [
        jax.ShapeDtypeStruct(tuple(leaf["shape"]), np.dtype(leaf["dtype"]))
        for leaf in manifest["leaves"]
    ]
    leaves, extra, step = ckpt.restore(checkpoint_dir, template, step=step)
    stored = extra.get("fingerprint")
    if fingerprint is not None and stored != fingerprint:
        raise SessionMismatch(
            f"snapshot under {checkpoint_dir} was written by a different "
            f"run:\n  stored:  {stored}\n  current: {fingerprint}")
    leaves = [np.asarray(leaf) for leaf in leaves]
    return decode_session(leaves, extra, cfg.metric), step
