"""Checkpointed mining sessions — the preemption-safe `mine()` driver.

A `MiningSession` wraps `repro.core.flexis.mine` with the level-boundary
and mid-level hooks the core exposes, and persists the full mining state
through `repro.train.checkpoint`'s atomic manifest/COMMIT protocol:

  * at **every level boundary** the whole loop state (frontier, stats,
    candidates of the next level, τ/telemetry bookkeeping) is snapshotted;
  * **inside a level**, the carried state of the in-flight pattern group
    is snapshotted every ``checkpoint_every`` state updates — one update
    per root block on the batched plane, one per logical super-block on
    the distributed plane — so a kill mid-pattern loses at most
    ``checkpoint_every`` blocks of device work;
  * device-side metric state (mIS bitmaps/counters, MNI/frac tables) is
    saved as full logical arrays, so a resumed session may run on a
    different device count/mesh shape than the one that wrote the
    snapshot (re-sharding happens on load); the distributed plane's
    logical super-block schedule (`MiningConfig.blocks_per_super`, pinned
    by the session) keeps its accounting mesh-invariant too.

Resume contract: ``MiningSession(...).run()`` on a directory holding a
snapshot continues the run and returns a `MiningResult` identical to the
uninterrupted run's, except wall-clock fields (``elapsed_s``, per-level
``wall_s``).  A crash *during* a save never corrupts the previous
snapshot (that is `train/checkpoint.py`'s COMMIT guarantee), so the worst
case is re-doing work since the last committed snapshot — never wrong
results.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.batched import GroupState, PatternOutcome
from repro.core.distributed import SuperBlockState
from repro.core.flexis import (
    MiningConfig, MiningLoopState, MiningResult, initial_candidates, mine,
)
from repro.core.graph import DataGraph
from repro.core.health import RunHealth
from repro.train import checkpoint as ckpt

from . import faults
from .state import (
    GroupDone, LevelCursor, SampledCursor, SessionState, encode_session,
)
from .resume import load_session, session_fingerprint

__all__ = ["MiningSession", "PreemptedError", "DEFAULT_BLOCKS_PER_SUPER"]


class PreemptedError(BaseException):
    """The session was asked to stop (`request_preempt`) and did so right
    after committing a snapshot — the run is consistent and resumable.

    A *BaseException* on purpose (like KeyboardInterrupt): no recovery
    path — plane fallback, save retry — may swallow a preemption request;
    only the top-level driver (`launch/mine.py`) catches it.
    """

# distributed-plane sessions pin the logical super-block width so the
# schedule (and with it every accounting field) survives a mesh reshape;
# 8 root blocks keeps ≤8-device meshes fully busy per super-block while
# bounding the work lost to a mid-super-block kill
DEFAULT_BLOCKS_PER_SUPER = 8


class _LevelRecorder:
    """Per-level hooks object handed to the level executors.

    Implements the duck-typed surface `evaluate_level_batched` /
    `evaluate_level_distributed` document: replays completed groups from
    the resume cursor, hands the in-flight group its carried state, and
    records every state update back into the session for snapshotting.
    """

    def __init__(self, session: "MiningSession", level: int,
                 resume_cursor: Optional[LevelCursor]):
        self._session = session
        self.level = level
        self.groups_done: List[GroupDone] = (
            list(resume_cursor.groups_done) if resume_cursor else [])
        self._resume = resume_cursor
        self.inflight_key: Optional[Tuple[int, int]] = None
        self.inflight_group: Optional[GroupState] = None
        self.inflight_super: Optional[SuperBlockState] = None
        self.plan: Optional[dict] = (
            resume_cursor.plan if resume_cursor else None)
        self.sampled: Optional[SampledCursor] = (
            resume_cursor.sampled if resume_cursor else None)

    # -- resume side --------------------------------------------------------
    def resume_plan(self) -> Optional[dict]:
        """The planner decision recorded for this level, or None (fresh
        level / forced execution) — `mine()` replays it verbatim."""
        return self._resume.plan if self._resume is not None else None
    def resume_outcomes(self) -> Optional[Dict[int, PatternOutcome]]:
        if not self.groups_done:
            return None
        return {i: o for gd in self.groups_done
                for i, o in zip(gd.idxs, gd.outcomes)}

    def resume_dispatches(self) -> int:
        return sum(gd.dispatches for gd in self.groups_done)

    def resume_block_peaks(self):
        """Element-wise max of the completed groups' per-block peak
        telemetry (block-id indexed), or None when no group recorded it."""
        peaks = None
        for gd in self.groups_done:
            if gd.block_peaks is None:
                continue
            arr = list(gd.block_peaks)
            if peaks is None:
                peaks = arr
            else:
                peaks = [max(a, b) for a, b in zip(peaks, arr)]
        return peaks

    def resume_replans(self) -> int:
        """Total within-level cap replans of the completed groups."""
        return sum(gd.replans for gd in self.groups_done)

    def resume_sampled(self) -> Optional[dict]:
        """The sampled-phase cursor recorded for this level, or None."""
        return (self._resume.sampled.to_dict()
                if self._resume is not None
                and self._resume.sampled is not None else None)

    def group_resume(self, k: int, lo: int):
        if self._resume is None or self._resume.inflight_key != (k, lo):
            return None
        return (self._resume.inflight_group
                if self._resume.inflight_group is not None
                else self._resume.inflight_super)

    # -- record side --------------------------------------------------------
    def record_plan(self, plan: dict) -> None:
        self.plan = plan

    def on_group_state(self, k: int, lo: int, state) -> None:
        self.inflight_key = (k, lo)
        if isinstance(state, SuperBlockState):
            self.inflight_super, self.inflight_group = state, None
        else:
            self.inflight_group, self.inflight_super = state, None
        self._session._on_state_update()

    def on_group_done(self, k: int, lo: int, idxs, outcomes,
                      dispatches: int, block_peaks=None,
                      replans: int = 0) -> None:
        self.groups_done.append(GroupDone(
            k=k, lo=lo, idxs=list(idxs), outcomes=list(outcomes),
            dispatches=dispatches,
            block_peaks=(None if block_peaks is None
                         else [int(x) for x in block_peaks]),
            replans=int(replans)))
        self.inflight_key = None
        self.inflight_group = None
        self.inflight_super = None

    def drop_inflight(self) -> None:
        """Discard the in-flight group/super-block state (plane fallback:
        a batched re-run of the level cannot consume a distributed
        super-block cursor — completed groups stay, they are plane-
        agnostic outcomes)."""
        self.inflight_key = None
        self.inflight_group = None
        self.inflight_super = None
        if self._resume is not None:
            # the resume cursor may hold the other plane's in-flight state
            # too (group_resume would hand it to the wrong executor)
            self._resume = dataclasses.replace(
                self._resume, inflight_key=None, inflight_group=None,
                inflight_super=None)

    def on_sampled(self, d: dict) -> None:
        """Sampled-phase snapshot point (after each sample group and when
        classification lands) — store the cursor and trigger the cadence."""
        self.sampled = SampledCursor.from_dict(d)
        self._session._on_state_update()

    def cursor(self) -> LevelCursor:
        return LevelCursor(
            level=self.level,
            groups_done=list(self.groups_done),
            inflight_key=self.inflight_key,
            inflight_group=self.inflight_group,
            inflight_super=self.inflight_super,
            plan=self.plan,
            sampled=self.sampled,
        )


class _SessionHooks:
    """The `mine()`-facing hooks surface (see `flexis.mine`)."""

    def __init__(self, session: "MiningSession",
                 resume_state: Optional[SessionState]):
        self._session = session
        self._resume = resume_state

    def loop_resume(self) -> Optional[MiningLoopState]:
        return self._resume.loop if self._resume is not None else None

    def pin_calibration(self, loaded: dict) -> dict:
        """Pin the planner's cost model to the session: a fresh run stores
        the loaded constants in every snapshot; a resumed run returns the
        stored ones, so replanning is identical across processes even if
        the calibration file changed in between."""
        if (self._resume is not None
                and self._resume.calibration is not None):
            self._session._calibration = self._resume.calibration
        else:
            self._session._calibration = loaded
        return self._session._calibration

    def level_hooks(self, level: int) -> _LevelRecorder:
        cursor = None
        if (self._resume is not None and self._resume.cursor is not None
                and self._resume.cursor.level == level):
            cursor = self._resume.cursor
        rec = _LevelRecorder(self._session, level, cursor)
        self._session._recorder = rec
        return rec

    def on_level_end(self, loop: MiningLoopState) -> None:
        self._session._on_level_end(loop)


class MiningSession:
    """A resumable mining run bound to a checkpoint directory.

    Args:
      g: the data graph (must be byte-identical across resumes — validated
        via a fingerprint stored in every snapshot).
      cfg: `MiningConfig`; on the distributed plane an unset
        ``blocks_per_super`` is pinned to `DEFAULT_BLOCKS_PER_SUPER`.
      checkpoint_dir: snapshot root (one `train/checkpoint.py` step per
        snapshot).
      checkpoint_every: snapshot cadence in carried-state updates (root
        blocks / super-blocks); level boundaries always snapshot.
        ``0`` disables mid-level snapshots (boundaries only).
      keep_last: retention, forwarded to `checkpoint.save`.
      resume: ``"auto"`` (continue a snapshot when one exists),
        ``"never"`` (ignore snapshots; fresh run), or ``"must"`` (raise
        unless a snapshot exists).
      meta: optional JSON-serializable dict stored in every snapshot
        (dataset provenance etc.; not validated on resume).
      async_saves: write mid-run snapshots through
        `checkpoint.save_async` (depth-1 write-behind: each snapshot first
        drains — and surfaces any error of — the previous one, so writes
        stay ordered and failures are never silent).  The final snapshot
        of a run is always synchronous.  ``False`` = every snapshot
        synchronous (the pre-PR-9 behavior).
      health: a `RunHealth` to record recoveries into (shared with
        `mine()`; a fresh one is created when omitted — read it back from
        ``MiningResult.health`` or ``session.health``).
    """

    def __init__(self, g: DataGraph, cfg: MiningConfig,
                 checkpoint_dir, *, checkpoint_every: int = 1,
                 keep_last: int = 3, resume: str = "auto",
                 meta: Optional[dict] = None, async_saves: bool = True,
                 health: Optional[RunHealth] = None):
        if resume not in ("auto", "never", "must"):
            raise ValueError('resume must be "auto", "never" or "must"')
        if checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if cfg.execution == "distributed" and cfg.blocks_per_super is None:
            cfg = dataclasses.replace(
                cfg, blocks_per_super=DEFAULT_BLOCKS_PER_SUPER)
        self.g = g
        self.cfg = cfg
        self.dir = Path(checkpoint_dir)
        self.checkpoint_every = int(checkpoint_every)
        self.keep_last = keep_last
        self.meta = meta or {}
        self._resume_mode = resume
        self._fingerprint = session_fingerprint(g, cfg)

        self._step = -1                 # last written snapshot step
        self._updates = 0               # state updates since last snapshot
        self._recorder: Optional[_LevelRecorder] = None
        self._boundary: Optional[MiningLoopState] = None
        self._calibration: Optional[dict] = None  # pinned planner constants
        self._t0 = 0.0
        self._elapsed0 = 0.0
        self.snapshots_written = 0
        self._async = bool(async_saves)
        self._final_save = False        # next _save is the run's last
        self._preempt_requested = False
        self.health = health if health is not None else RunHealth()

    def request_preempt(self) -> None:
        """Ask the run to stop at the next snapshot point.

        Signal-handler safe (sets a flag).  The driver keeps mining until
        the next snapshot is fully committed — mid-level cadence permitting,
        at most ``checkpoint_every`` state updates away — then raises
        `PreemptedError` out of `run()`.  The directory then holds a
        consistent snapshot; a later run resumes it bit-identically.
        """
        self._preempt_requested = True

    # -- persistence --------------------------------------------------------
    def _elapsed(self) -> float:
        return self._elapsed0 + (time.monotonic() - self._t0)

    def _drain_pending(self) -> None:
        """Join in-flight background writes, surfacing collected errors.

        The first error is recorded in `RunHealth` and re-raised — a
        background snapshot write failing is a *caller's* problem (the
        run's durability story just changed), never a daemon thread's.
        """
        errs = ckpt.wait_pending(raise_errors=False)
        if errs:
            self.health.record(
                "save_async_failure",
                f"background snapshot write failed: "
                f"{type(errs[0]).__name__}: {errs[0]}", step=self._step)
            raise errs[0]

    def _save(self, state: SessionState) -> None:
        if state.calibration is None:
            state = dataclasses.replace(state, calibration=self._calibration)
        leaves, extra = encode_session(state, self.cfg.metric)
        extra["fingerprint"] = self._fingerprint
        extra["meta"] = self.meta
        self._step += 1
        # depth-1 write-behind: drain (and surface any failure of) the
        # previous background write before starting the next, so snapshot
        # writes stay ordered and at most one overlaps compute
        self._drain_pending()
        sync = (not self._async or self._final_save
                or self._preempt_requested)
        if sync:
            ckpt.save(self.dir, self._step, leaves, extra=extra,
                      keep_last=self.keep_last, health=self.health)
        else:
            ckpt.save_async(self.dir, self._step, leaves, extra=extra,
                            keep_last=self.keep_last, health=self.health)
        self.snapshots_written += 1
        self._updates = 0
        faults.fire("session.snapshot", step=self._step)
        if self._preempt_requested:
            self.health.record(
                "preempted", f"stopped after committed snapshot "
                f"step {self._step}", step=self._step)
            raise PreemptedError(
                f"preempted; snapshot step {self._step} committed under "
                f"{self.dir} — resume to continue")

    def _on_state_update(self) -> None:
        """Called by the recorder after every carried-state update."""
        self._updates += 1
        if not self._preempt_requested:  # a preempt snapshots immediately
            if self.checkpoint_every == 0:
                return
            if self._updates < self.checkpoint_every:
                return
        boundary = self._boundary
        assert boundary is not None and self._recorder is not None
        loop = dataclasses.replace(boundary, elapsed_s=self._elapsed())
        self._save(SessionState(loop=loop, cursor=self._recorder.cursor()))

    def _on_level_end(self, loop: MiningLoopState) -> None:
        self._boundary = loop
        self._recorder = None
        # the final boundary (no candidates left) is the run's last write:
        # always synchronous, so `run()` returning implies durability
        self._final_save = not loop.cp
        self._save(SessionState(loop=loop))

    # -- driver -------------------------------------------------------------
    def run(self) -> MiningResult:
        """Mine (or continue mining) and return the `MiningResult`."""
        # drain writes a previous in-process session may have left in
        # flight (the fault-matrix tests resume in-process); their errors
        # are recorded, not raised — the snapshot they failed to write is
        # simply not there to resume from
        for e in ckpt.wait_pending(raise_errors=False):
            self.health.record(
                "save_async_failure",
                f"prior background snapshot write failed: "
                f"{type(e).__name__}: {e}")
        resume_state: Optional[SessionState] = None
        if self._resume_mode != "never":
            loaded = load_session(self.dir, self.cfg,
                                  fingerprint=self._fingerprint,
                                  health=self.health)
            if loaded is None and self._resume_mode == "must":
                raise FileNotFoundError(
                    f"resume='must' but no committed session snapshot "
                    f"under {self.dir}")
            if loaded is not None:
                resume_state, self._step = loaded
        if resume_state is not None:
            self._elapsed0 = resume_state.loop.elapsed_s
            self._boundary = resume_state.loop
        else:
            # synthesize the level-0 boundary so a kill inside the very
            # first level still has a base snapshot to hang its cursor on
            self._boundary = MiningLoopState(
                level=0, cp=initial_candidates(self.g), frequent=[],
                stats=[], per_level={}, searched=0,
                peak_bytes=self.g.nbytes(), elapsed_s=0.0)
        self._t0 = time.monotonic()
        hooks = _SessionHooks(self, resume_state)
        res = mine(self.g, self.cfg, hooks=hooks, health=self.health)
        # the final boundary save is synchronous, so normally nothing is
        # pending here; a run with no levels at all never saved — either
        # way this is a cheap invariant, not a flush
        self._drain_pending()
        return res
