"""Deterministic fault injection for the mining runtime.

The fault-tolerance layer (checksummed checkpoints, COMMIT-chain fallback,
save retries, plane degradation — see docs/architecture.md "Fault
tolerance") is only as trustworthy as the failures it was tested against.
This module makes those failures *reproducible*: a seeded `FaultPlan`
holds a list of `FaultSpec`s, each naming an **injection point** the
runtime fires on its hot path, the arrival index at which to trigger, and
the fault class to inject.  CI enumerates the full fault × point matrix
(`tests/runtime/test_faults.py`) and asserts every cell completes with
results bit-identical to the fault-free oracle.

Injection points (fired via the module-level `fire`; zero work when no
plan is installed):

  * ``save.io``           — start of every checkpoint write attempt
                            (inside the retry loop: transient-I/O class)
  * ``save.array_write``  — after each array file lands in the tmp dir
                            (``path`` = the file: torn-write class)
  * ``save.manifest``     — after the manifest lands in the tmp dir
                            (``path`` = the file: corruption class)
  * ``save.pre_commit``   — after the tmp→final rename, before COMMIT
                            (crash-inside-save class)
  * ``save.committed``    — after COMMIT (``path`` = the step dir:
                            post-hoc bit-rot class)
  * ``session.snapshot``  — after a session snapshot is fully persisted
                            (kill-at-snapshot class)
  * ``level.distributed`` — entry of the distributed level executor
                            (mesh-failure class → plane fallback)

Fault kinds:

  * ``crash``            — raise `InjectedCrash` (stands in for SIGKILL;
                           a *BaseException* so no recovery path may
                           swallow it — only the test driver catches it)
  * ``io_error``         — raise ``OSError(errno)`` (default ``EIO``;
                           transient when fired fewer times than the
                           save retry budget)
  * ``error``            — raise `InjectedFault` (a plain RuntimeError:
                           the recoverable-failure class, e.g. a mesh
                           going away under the distributed plane)
  * ``torn_write``       — truncate the file at ``path`` to half its
                           bytes, then raise `InjectedCrash`
  * ``bitflip``          — flip one seeded bit of one seeded ``arr_*.npy``
                           under ``path`` (no raise — silent bit-rot)
  * ``corrupt_manifest`` — overwrite the file at ``path`` with truncated
                           garbage (no raise)

Plans come from code (`install`) or from the ``REPRO_FAULT_PLAN`` env var
(JSON, see `FaultPlan.from_env`) so subprocess/CI runs can be injected
without touching the command line.
"""
from __future__ import annotations

import dataclasses
import errno as errno_lib
import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = [
    "FAULT_PLAN_ENV", "FaultSpec", "FaultPlan", "InjectedCrash",
    "InjectedFault", "install", "clear", "active", "fire", "POINTS", "KINDS",
]

FAULT_PLAN_ENV = "REPRO_FAULT_PLAN"

POINTS = (
    "save.io", "save.array_write", "save.manifest", "save.pre_commit",
    "save.committed", "session.snapshot", "level.distributed",
)
KINDS = ("crash", "io_error", "error", "torn_write", "bitflip",
         "corrupt_manifest")


class InjectedCrash(BaseException):
    """An injected hard kill.  Deliberately NOT an `Exception`: recovery
    code catching ``Exception`` must treat this like SIGKILL (i.e. not at
    all) — only the fault-matrix test driver catches it."""


class InjectedFault(RuntimeError):
    """An injected recoverable failure (the ``error`` kind)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: fire ``kind`` at arrivals [at, at+times) of ``point``."""

    point: str
    kind: str
    at: int = 1          # 1-based arrival index of the first firing
    times: int = 1       # consecutive arrivals that fire
    errno_name: str = "EIO"   # io_error kind only: EIO / ENOSPC / ...

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(f"unknown injection point {self.point!r}; "
                             f"must be one of {POINTS}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"must be one of {KINDS}")
        if self.at < 1 or self.times < 1:
            raise ValueError("at and times must be >= 1")
        if not hasattr(errno_lib, self.errno_name):
            raise ValueError(f"unknown errno {self.errno_name!r}")

    def to_dict(self) -> Dict[str, Any]:
        return {"point": self.point, "kind": self.kind, "at": self.at,
                "times": self.times, "errno": self.errno_name}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(point=str(d["point"]), kind=str(d["kind"]),
                   at=int(d.get("at", 1)), times=int(d.get("times", 1)),
                   errno_name=str(d.get("errno", "EIO")))


class FaultPlan:
    """A seeded set of `FaultSpec`s with per-point arrival counters.

    Thread-safe: checkpoint writes may fire points from a background
    thread.  ``hits`` counts arrivals per point; ``fired`` logs every
    fault actually injected (the tests assert against it).
    """

    def __init__(self, specs: List[FaultSpec], *, seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self.hits: Dict[str, int] = {}
        self.fired: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    @classmethod
    def from_env(cls, environ=None) -> Optional["FaultPlan"]:
        """Parse ``REPRO_FAULT_PLAN`` — either a JSON list of spec dicts or
        ``{"seed": int, "faults": [...]}``.  Returns None when unset."""
        raw = (environ if environ is not None else os.environ).get(
            FAULT_PLAN_ENV)
        if not raw:
            return None
        d = json.loads(raw)
        if isinstance(d, list):
            d = {"faults": d}
        specs = [FaultSpec.from_dict(s) for s in d.get("faults", [])]
        return cls(specs, seed=int(d.get("seed", 0)))

    # -- firing -------------------------------------------------------------
    def fire(self, point: str, *, path=None, step: Optional[int] = None
             ) -> None:
        with self._lock:
            n = self.hits.get(point, 0) + 1
            self.hits[point] = n
            due = [s for s in self.specs
                   if s.point == point and s.at <= n < s.at + s.times]
            for s in due:
                self.fired.append({**s.to_dict(), "arrival": n,
                                   "step": step})
        for s in due:
            self._act(s, n, path=path, step=step)

    def _act(self, spec: FaultSpec, arrival: int, *, path, step) -> None:
        where = f"{spec.point} (arrival {arrival}, step {step})"
        if spec.kind == "crash":
            raise InjectedCrash(f"injected crash at {where}")
        if spec.kind == "io_error":
            err = getattr(errno_lib, spec.errno_name)
            raise OSError(err, f"injected {spec.errno_name} at {where}")
        if spec.kind == "error":
            raise InjectedFault(f"injected failure at {where}")
        if spec.kind == "torn_write":
            f = Path(path)
            data = f.read_bytes()
            f.write_bytes(data[: len(data) // 2])
            raise InjectedCrash(f"injected torn write at {where} ({f.name})")
        if spec.kind == "bitflip":
            root = Path(path)
            files = (sorted(root.glob("arr_*.npy")) if root.is_dir()
                     else [root])
            # flip a payload bit, not the .npy header — header damage is
            # caught by np.load itself; the CRC must catch *silent* rot
            # (so prefer files that actually carry payload past the
            # 128-byte header block)
            payload = [f for f in files if f.stat().st_size > 128]
            files = payload or files
            rng = np.random.default_rng(self.seed * 1_000_003 + arrival)
            f = files[int(rng.integers(len(files)))]
            data = bytearray(f.read_bytes())
            lo = min(128, len(data) - 1)
            pos = int(rng.integers(lo, len(data)))
            data[pos] ^= 1 << int(rng.integers(8))
            f.write_bytes(bytes(data))
            return
        if spec.kind == "corrupt_manifest":
            Path(path).write_text('{"format_version": 2, "truncat')
            return
        raise AssertionError(f"unhandled fault kind {spec.kind}")


# -- process-wide installed plan --------------------------------------------
_PLAN: Optional[FaultPlan] = None
_ENV_CHECKED = False
_LOCK = threading.Lock()


def install(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install ``plan`` process-wide (None uninstalls).  Returns it."""
    global _PLAN, _ENV_CHECKED
    with _LOCK:
        _PLAN = plan
        _ENV_CHECKED = True   # an explicit install overrides the env
    return plan


def clear() -> None:
    """Remove any installed plan and re-arm env-var pickup."""
    global _PLAN, _ENV_CHECKED
    with _LOCK:
        _PLAN = None
        _ENV_CHECKED = False


def active() -> Optional[FaultPlan]:
    """The installed plan, lazily picking up ``REPRO_FAULT_PLAN`` once."""
    global _PLAN, _ENV_CHECKED
    if _PLAN is None and not _ENV_CHECKED:
        with _LOCK:
            if _PLAN is None and not _ENV_CHECKED:
                _PLAN = FaultPlan.from_env()
                _ENV_CHECKED = True
    return _PLAN


def fire(point: str, *, path=None, step: Optional[int] = None) -> None:
    """Fire an injection point.  No-op (one None check) without a plan."""
    plan = active()
    if plan is not None:
        plan.fire(point, path=path, step=step)
