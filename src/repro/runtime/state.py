"""Session-state codec — the mining loop's full state as (pytree, extra).

A mining session snapshot has two halves, mirroring what
`train/checkpoint.py` can carry:

  * the **pytree**: the device-side metric state of the in-flight group —
    mIS bitmaps/counters (batched `GroupState` or distributed
    `SuperBlockState`), MNI image tables, fractional count tables — saved
    as *full logical arrays*, so a restore can re-shard onto any mesh;
  * the **extra** manifest slot: every host-side value — the per-level
    frequent-pattern frontier (patterns + `PatternStats`), the candidate
    list of the next level, τ/accounting bookkeeping, and the
    level/pattern-group/block cursor — encoded as plain JSON.

`encode_session` / `decode_session` are exact inverses for every field
that participates in the resume bit-identity contract (wall-clock floats
round-trip through JSON unchanged — Python floats are IEEE doubles both
sides).  The pytree is a flat *list* of arrays; ``extra["pytree"]``
records how many leaves the in-flight state owns and the metric decides
their structure, which is what lets `resume.load_session` rebuild the
tree without knowing shapes up front (shapes live in the checkpoint
manifest).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.batched import GroupState, PatternOutcome
from repro.core.distributed import SuperBlockState
from repro.core.flexis import MiningLoopState, PatternStats
from repro.core.pattern import Pattern

__all__ = [
    "FORMAT", "GroupDone", "LevelCursor", "SampledCursor", "SessionState",
    "encode_session", "decode_session",
    "encode_pattern", "decode_pattern",
]

FORMAT = 1


# ---------------------------------------------------------------------------
# host-object codecs (JSON-dict ⟷ dataclass)
# ---------------------------------------------------------------------------

def encode_pattern(p: Pattern) -> Dict[str, Any]:
    return {"labels": p.labels.tolist(), "edges": p.edges()}


def decode_pattern(d: Dict[str, Any]) -> Pattern:
    labels = np.asarray(d["labels"], np.int32)
    adj = np.zeros((labels.shape[0], labels.shape[0]), bool)
    for i, j in d["edges"]:
        adj[i, j] = True
    return Pattern(adj, labels)


def _encode_stats(st: PatternStats) -> Dict[str, Any]:
    return {
        "pattern": encode_pattern(st.pattern),
        "support": int(st.support),
        "tau": int(st.tau),
        "frequent": bool(st.frequent),
        "embeddings_found": int(st.embeddings_found),
        "overflowed": bool(st.overflowed),
        "blocks_run": int(st.blocks_run),
        "max_count": int(st.max_count),
        "dispatches": int(st.dispatches),
        "estimated": bool(st.estimated),
    }


def _decode_stats(d: Dict[str, Any]) -> PatternStats:
    return PatternStats(
        pattern=decode_pattern(d["pattern"]),
        support=d["support"],
        tau=d["tau"],
        frequent=d["frequent"],
        embeddings_found=d["embeddings_found"],
        overflowed=d["overflowed"],
        blocks_run=d["blocks_run"],
        max_count=d.get("max_count", 0),
        dispatches=d.get("dispatches", 0),
        estimated=d.get("estimated", False),
    )


def _encode_outcome(o: PatternOutcome) -> Dict[str, Any]:
    return {
        "support": int(o.support),
        "frequent": bool(o.frequent),
        "embeddings_found": int(o.embeddings_found),
        "overflowed": bool(o.overflowed),
        "blocks_run": int(o.blocks_run),
        "max_count": int(o.max_count),
        "estimated": bool(o.estimated),
    }


def _decode_outcome(d: Dict[str, Any]) -> PatternOutcome:
    return PatternOutcome(**d)


def _encode_loop(loop: MiningLoopState) -> Dict[str, Any]:
    return {
        "level": loop.level,
        "cp": [encode_pattern(p) for p in loop.cp],
        "frequent": [
            {"pattern": encode_pattern(p), "support": int(s)}
            for p, s in loop.frequent
        ],
        "stats": [_encode_stats(st) for st in loop.stats],
        "per_level": {str(k): v for k, v in loop.per_level.items()},
        "searched": loop.searched,
        "peak_bytes": loop.peak_bytes,
        "elapsed_s": loop.elapsed_s,
        "timed_out": loop.timed_out,
    }


def _decode_loop(d: Dict[str, Any]) -> MiningLoopState:
    return MiningLoopState(
        level=d["level"],
        cp=[decode_pattern(p) for p in d["cp"]],
        frequent=[(decode_pattern(f["pattern"]), f["support"])
                  for f in d["frequent"]],
        stats=[_decode_stats(st) for st in d["stats"]],
        per_level={int(k): v for k, v in d["per_level"].items()},
        searched=d["searched"],
        peak_bytes=d["peak_bytes"],
        elapsed_s=d["elapsed_s"],
        timed_out=d["timed_out"],
    )


# ---------------------------------------------------------------------------
# session state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GroupDone:
    """One completed (k, lo) group of the in-flight level."""

    k: int
    lo: int
    idxs: List[int]                     # level eval-set indices
    outcomes: List[PatternOutcome]
    dispatches: int
    # per-block-id peak frontier occupancy over the blocks this group ran
    # (length = total root blocks) — the sampled plane's next-level draw
    # weights; None for snapshots written before the sampled plane existed
    block_peaks: Optional[List[int]] = None
    # within-level cap replans this group performed (auto plane only)
    replans: int = 0


@dataclasses.dataclass
class SampledCursor:
    """Mid-level resume state specific to the sampled plane.

    ``phase`` is ``"sample"`` (the weighted sample pass is running; the
    completed groups live in ``groups``) or ``"escalate"`` (classification
    finished — ``classify`` pins its verdicts — and the exact escalation
    pass is running, its own group progress tracked by the ordinary
    `LevelCursor` machinery).  ``positions``/``key`` replay the draw
    verbatim so a resume never re-samples.
    """

    phase: str                          # "sample" | "escalate"
    positions: List[int]                # round-0 schedule indices (asc)
    key: List[int]                      # RNG key words of the draw
    # completed sample-pass groups, keyed "k:lo:r<round>" →
    # {"idxs", "ys" (per-pattern per-block increments), "outcomes",
    #  "dispatches", "block_peaks", "replay" (escalation-reuse records)}
    groups: Dict[str, dict]
    # phase == "escalate" only: {"escalate" (eval-set indices),
    # "pruned" (str(idx) → outcome dict), "rounds", "ci_width_mean"}
    classify: Optional[dict] = None
    # adaptive rounds past the plan's round 0, in order:
    # {"round", "n_new", "positions", "pis"} — replayed verbatim so a
    # resume never re-draws a committed round
    rounds: List[dict] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "phase": self.phase,
            "positions": [int(x) for x in self.positions],
            "key": [int(x) for x in self.key],
            "rounds": self.rounds,
            "groups": self.groups,
            "classify": self.classify,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SampledCursor":
        return cls(
            phase=str(d["phase"]),
            positions=[int(x) for x in d["positions"]],
            key=[int(x) for x in d["key"]],
            rounds=list(d.get("rounds") or []),
            groups=dict(d.get("groups") or {}),
            classify=d.get("classify"),
        )


@dataclasses.dataclass
class LevelCursor:
    """Mid-level resume state: which groups of the in-flight level finished
    and the carried state of the one that was running when we snapshotted."""

    level: int
    groups_done: List[GroupDone]
    inflight_key: Optional[Tuple[int, int]] = None       # (k, lo)
    # exactly one of these, matching the execution plane:
    inflight_group: Optional[GroupState] = None          # batched
    inflight_super: Optional[SuperBlockState] = None     # distributed
    # the planner's recorded decision for the in-flight level
    # (`LevelPlan.to_dict()`; None under forced execution modes *except*
    # "sampled", which records the level's block draw here) — a resume
    # replays this instead of re-planning, so calibration drift between
    # processes cannot move an in-flight level's plan
    plan: Optional[Dict[str, Any]] = None
    # sampled plane only: the sample-pass / escalation phase cursor
    sampled: Optional[SampledCursor] = None


@dataclasses.dataclass
class SessionState:
    """A full mining-session snapshot: the last level-boundary loop state
    plus (optionally) the cursor into the level running past it."""

    loop: MiningLoopState
    cursor: Optional[LevelCursor] = None
    # the pinned planner cost model (`CostModel.to_dict()`): the session
    # stores the constants the run planned with, so a resumed process
    # replans future levels with the *same* model even if the calibration
    # file changed (or vanished) in between
    calibration: Optional[Dict[str, Any]] = None


# ---------------------------------------------------------------------------
# (pytree, extra) codec
# ---------------------------------------------------------------------------

def _mis_state(metric: str) -> bool:
    return metric in ("mis", "mis_luby")


def encode_session(state: SessionState, metric: str,
                   ) -> Tuple[List[np.ndarray], Dict[str, Any]]:
    """Flatten a `SessionState` into (array leaves, JSON extra).

    The leaves are the in-flight device state as logical host arrays (empty
    when the snapshot sits exactly on a level boundary); everything else
    goes into ``extra``.  ``extra["cursor"]`` is the compact
    level/pattern-group/block index `train/checkpoint.py` documents as the
    resumable-cursor slot.
    """
    leaves: List[np.ndarray] = []
    extra: Dict[str, Any] = {
        "format": FORMAT,
        "loop": _encode_loop(state.loop),
        "cursor": {"level": state.loop.level, "group": None, "block": None},
    }
    if state.calibration is not None:
        extra["calibration"] = state.calibration
    if state.cursor is None:
        extra["pytree"] = {"kind": "none", "n_leaves": 0}
        return leaves, extra

    cur = state.cursor
    c: Dict[str, Any] = {
        "level": cur.level,
        "groups_done": [
            {
                "k": gd.k, "lo": gd.lo, "idxs": list(map(int, gd.idxs)),
                "outcomes": [_encode_outcome(o) for o in gd.outcomes],
                "dispatches": gd.dispatches,
                "block_peaks": (None if gd.block_peaks is None
                                else [int(x) for x in gd.block_peaks]),
                "replans": int(gd.replans),
            }
            for gd in cur.groups_done
        ],
        "inflight_key": (list(cur.inflight_key)
                         if cur.inflight_key is not None else None),
        "plan": cur.plan,
        "sampled": (cur.sampled.to_dict()
                    if cur.sampled is not None else None),
    }
    extra["cursor"]["level"] = cur.level
    if cur.inflight_group is not None:
        gs = cur.inflight_group
        devstate = gs.state if _mis_state(metric) else (gs.state,)
        leaves = [np.asarray(leaf) for leaf in devstate]
        gs_max = (gs.max_count if gs.max_count is not None
                  else np.zeros_like(gs.supports))
        c["inflight"] = {
            "plane": "batched",
            "next_block": int(gs.next_block),
            "bucket_map": np.asarray(gs.bucket_map).tolist(),
            "supports": gs.supports.tolist(),
            "found": gs.found.tolist(),
            "overflowed": gs.overflowed.tolist(),
            "blocks_run": gs.blocks_run.tolist(),
            "dispatches": int(gs.dispatches),
            "max_count": gs_max.tolist(),
            "block_peaks": (None if gs.block_peaks is None
                            else np.asarray(gs.block_peaks).tolist()),
            "cap": (None if gs.cap is None else int(gs.cap)),
            "replans": int(gs.replans),
        }
        extra["cursor"]["group"] = list(cur.inflight_key)
        extra["cursor"]["block"] = int(gs.next_block)
    elif cur.inflight_super is not None:
        ss = cur.inflight_super
        leaves = [np.asarray(ss.bitmaps), np.asarray(ss.counts)]
        ss_max = (ss.max_count if ss.max_count is not None
                  else np.zeros_like(ss.found))
        c["inflight"] = {
            "plane": "distributed",
            "next_block": int(ss.next_block),
            "found": ss.found.tolist(),
            "overflowed": ss.overflowed.tolist(),
            "blocks_run": ss.blocks_run.tolist(),
            "super_blocks_run": int(ss.super_blocks_run),
            "dispatches": int(ss.dispatches),
            "max_count": ss_max.tolist(),
        }
        extra["cursor"]["group"] = list(cur.inflight_key)
        extra["cursor"]["block"] = int(ss.next_block)
    else:
        c["inflight"] = None
    extra["level_cursor"] = c
    extra["pytree"] = {"kind": ("mis" if _mis_state(metric) else metric)
                       if leaves else "none",
                       "n_leaves": len(leaves)}
    return leaves, extra


def decode_session(leaves: List[np.ndarray], extra: Dict[str, Any],
                   metric: str) -> SessionState:
    """Inverse of `encode_session` (leaves come back as logical arrays)."""
    if extra.get("format") != FORMAT:
        raise ValueError(
            f"unknown session snapshot format {extra.get('format')!r} "
            f"(this build reads format {FORMAT})")
    loop = _decode_loop(extra["loop"])
    calibration = extra.get("calibration")
    c = extra.get("level_cursor")
    if c is None:
        return SessionState(loop=loop, calibration=calibration)

    cursor = LevelCursor(
        level=c["level"],
        groups_done=[
            GroupDone(
                k=gd["k"], lo=gd["lo"], idxs=list(gd["idxs"]),
                outcomes=[_decode_outcome(o) for o in gd["outcomes"]],
                dispatches=gd["dispatches"],
                block_peaks=gd.get("block_peaks"),
                replans=int(gd.get("replans", 0)),
            )
            for gd in c["groups_done"]
        ],
        inflight_key=(tuple(c["inflight_key"])
                      if c["inflight_key"] is not None else None),
        plan=c.get("plan"),
        sampled=(SampledCursor.from_dict(c["sampled"])
                 if c.get("sampled") is not None else None),
    )
    inflight = c.get("inflight")
    n_leaves = extra["pytree"]["n_leaves"]
    if inflight is not None and n_leaves != len(leaves):
        raise ValueError(f"leaf count mismatch: {n_leaves} vs {len(leaves)}")
    if inflight is not None and inflight["plane"] == "batched":
        devstate = (tuple(leaves) if _mis_state(metric) else leaves[0])
        cursor.inflight_group = GroupState(
            next_block=inflight["next_block"],
            bucket_map=np.asarray(inflight["bucket_map"], np.int64),
            state=devstate,
            supports=np.asarray(inflight["supports"], np.int64),
            found=np.asarray(inflight["found"], np.int64),
            overflowed=np.asarray(inflight["overflowed"], bool),
            blocks_run=np.asarray(inflight["blocks_run"], np.int64),
            dispatches=inflight["dispatches"],
            max_count=np.asarray(
                inflight.get("max_count",
                             [0] * len(inflight["supports"])), np.int64),
            block_peaks=(None if inflight.get("block_peaks") is None
                         else np.asarray(inflight["block_peaks"], np.int64)),
            cap=inflight.get("cap"),
            replans=int(inflight.get("replans", 0)),
        )
    elif inflight is not None and inflight["plane"] == "distributed":
        cursor.inflight_super = SuperBlockState(
            next_block=inflight["next_block"],
            bitmaps=leaves[0],
            counts=leaves[1],
            found=np.asarray(inflight["found"], np.int64),
            overflowed=np.asarray(inflight["overflowed"], bool),
            blocks_run=np.asarray(inflight["blocks_run"], np.int64),
            super_blocks_run=inflight["super_blocks_run"],
            dispatches=inflight["dispatches"],
            max_count=np.asarray(
                inflight.get("max_count",
                             [0] * len(inflight["found"])), np.int64),
        )
    return SessionState(loop=loop, cursor=cursor, calibration=calibration)
