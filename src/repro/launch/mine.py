"""FLEXIS mining launcher — the paper's end-to-end driver.

    PYTHONPATH=src python -m repro.launch.mine --dataset gnutella \
        --scale 0.05 --sigma 30 --lam 0.4 --metric mis

Loads (synthesizes) a dataset, mines frequent subgraphs with the configured
metric/generation strategy, prints the paper's telemetry (per-level counts,
searched patterns, memory, time).  ``--execution distributed`` shards match
roots over every local device; ``--checkpoint-dir`` makes the run a
resumable *session* (`repro.runtime`) that snapshots the full mining state
at level-boundary and block/super-block granularity, and ``--resume``
continues one after a kill — on the same or a different device count —
with a bit-identical result.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import MatchConfig, MiningConfig, mine
from repro.core.flexis import tau_threshold
from repro.data.synthetic import PAPER_DATASETS, paper_dataset

# distinct "preempted, resumable" status: the run was stopped on request
# (SIGTERM/SIGINT) after committing a final snapshot — rerunning the same
# command line resumes it.  75 = BSD EX_TEMPFAIL ("temporary failure,
# retry"), which is exactly the contract.
EXIT_PREEMPTED = 75


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gnutella",
                    choices=sorted(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.05,
                    help="dataset size multiplier (1.0 = paper size)")
    ap.add_argument("--sigma", type=int, default=20)
    ap.add_argument("--lam", type=float, default=0.4)
    ap.add_argument("--metric", default="mis",
                    choices=["mis", "mis_luby", "mni", "frac"])
    ap.add_argument("--generation", default="merge",
                    choices=["merge", "edge_ext"])
    ap.add_argument("--execution", default="auto",
                    choices=["auto", "batched", "sequential", "distributed",
                             "sampled"],
                    help="data plane: cost-model planner picks per level "
                         "(auto, default; decisions recorded in per_level "
                         "and --json), one vmapped program per same-k "
                         "candidate group (batched), the paper's "
                         "per-pattern loop (sequential oracle), match "
                         "roots sharded over every local device "
                         "(distributed; forces metric=mis_luby), or a "
                         "weighted root-block sample with exact escalation "
                         "(sampled; same frequent set as batched — see "
                         "--sample-fraction/--confidence)")
    ap.add_argument("--sample-fraction", type=float, default=0.25,
                    help="sampled plane: target fraction of root blocks "
                         "drawn per level (1.0 degenerates to the exact "
                         "batched plane)")
    ap.add_argument("--confidence", type=float, default=0.95,
                    help="sampled plane: nominal CI level of the support "
                         "estimator — patterns whose interval reaches tau "
                         "escalate to the exact plane")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="sampled plane: RNG key root of the per-level "
                         "block draws (part of the session fingerprint)")
    ap.add_argument("--sample-rounds", type=int, default=3,
                    help="sampled plane: max adaptive draw rounds per "
                         "level — each round doubles block coverage for "
                         "the still-undecided patterns until the "
                         "undecided set stops shrinking (1 = the single "
                         "--sample-fraction draw)")
    ap.add_argument("--root-order", default="degree",
                    choices=["degree", "vertex"],
                    help="root-block schedule: highest max-out-degree "
                         "blocks first (degree, default — τ early exit "
                         "fires sooner) or legacy vertex-id order")
    ap.add_argument("--calibration", default=None,
                    help="planner calibration JSON (benchmarks/calibrate.py"
                         "); default: $REPRO_PLANNER_CALIBRATION, then "
                         "./planner_calibration.json, then built-in "
                         "defaults")
    ap.add_argument("--expansion", default="xla",
                    choices=["xla", "pallas"],
                    help="expansion plane inside match_block: per-chunk XLA "
                         "op pipeline (reference) or the fused Pallas "
                         "frontier kernel — bit-identical to the "
                         "single-phase xla pipeline (when a level overflows "
                         "cap, truncation content may differ from the "
                         "two-phase xla pipeline; overflow is always "
                         "flagged)")
    ap.add_argument("--pallas-interpret", default="auto",
                    choices=["auto", "on", "off"],
                    help="run the Pallas kernel in interpret mode: auto = "
                         "off on TPU, on elsewhere (interpret is required "
                         "off-TPU; the fused lowering only exists on TPU)")
    ap.add_argument("--root-block", type=int, default=None,
                    help="root-block width override (default: sized by "
                         "MatchConfig.for_graph).  The sampled plane draws "
                         "at root-block granularity — a graph the default "
                         "geometry covers in one block has nothing to "
                         "sample, so shrink this to turn estimation on")
    ap.add_argument("--max-size", type=int, default=4)
    ap.add_argument("--time-limit", type=float, default=1800.0,
                    help="paper uses a 30-minute timeout")
    ap.add_argument("--cap", type=int, default=16384)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write result JSON here")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="run as a resumable session: snapshot the full "
                         "mining state into this directory (atomic "
                         "manifest/COMMIT protocol, see repro.runtime)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="snapshot cadence in carried-state updates (root "
                         "blocks on the batched plane, super-blocks on the "
                         "distributed plane); 0 = level boundaries only")
    ap.add_argument("--resume", action="store_true",
                    help="require a committed snapshot in --checkpoint-dir "
                         "and continue it (without this flag a snapshot is "
                         "still picked up when present; --resume makes a "
                         "missing one an error instead of a fresh start)")
    args = ap.parse_args(argv)

    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    if args.execution == "distributed" and args.metric != "mis_luby":
        print(f"[mine] execution=distributed forces metric=mis_luby "
              f"(was {args.metric})")
        args.metric = "mis_luby"
    if args.calibration:
        import os

        from repro.core.planner import CALIBRATION_ENV

        os.environ[CALIBRATION_ENV] = args.calibration

    t0 = time.monotonic()
    g = paper_dataset(args.dataset, scale=args.scale, seed=args.seed)
    print(f"[mine] {args.dataset}×{args.scale}: |V|={g.n} |E|={g.n_edges} "
          f"labels={g.n_labels} (load {time.monotonic() - t0:.1f}s)")

    import dataclasses as _dc

    import jax as _jax

    interpret = (_jax.default_backend() != "tpu"
                 if args.pallas_interpret == "auto"
                 else args.pallas_interpret == "on")
    cfg = MiningConfig(
        sigma=args.sigma, lam=args.lam, metric=args.metric,
        generation=args.generation, max_pattern_size=args.max_size,
        time_limit_s=args.time_limit, execution=args.execution,
        root_order=args.root_order,
        sample_fraction=args.sample_fraction, confidence=args.confidence,
        sample_seed=args.sample_seed, sample_rounds=args.sample_rounds,
        match=_dc.replace(
            MatchConfig.for_graph(g, cap=args.cap, expansion=args.expansion),
            pallas_interpret=interpret,
            **({"root_block": args.root_block}
               if args.root_block is not None else {})),
    )
    if args.checkpoint_dir:
        import signal

        from repro.runtime import MiningSession, PreemptedError
        from repro.train import checkpoint as ckpt

        session = MiningSession(
            g, cfg, args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            resume="must" if args.resume else "auto",
            meta={"dataset": args.dataset, "scale": args.scale,
                  "seed": args.seed})

        # graceful shutdown: SIGTERM/SIGINT ask the session to stop at the
        # next snapshot point instead of dying mid-write; the session cuts
        # one final COMMIT'd snapshot and raises PreemptedError
        def _on_signal(signum, frame):
            print(f"[mine] caught signal {signum}: finishing the current "
                  f"snapshot, then exiting resumable", flush=True)
            session.request_preempt()

        prev_handlers = {s: signal.signal(s, _on_signal)
                         for s in (signal.SIGTERM, signal.SIGINT)}
        try:
            res = session.run()
        except PreemptedError as e:
            ckpt.wait_pending(raise_errors=False)  # flush async writes
            print(f"[mine] preempted: {e}")
            print(f"[mine] session: {session.snapshots_written} snapshots "
                  f"written under {args.checkpoint_dir}")
            return EXIT_PREEMPTED
        finally:
            for s, h in prev_handlers.items():
                signal.signal(s, h)
        print(f"[mine] session: {session.snapshots_written} snapshots "
              f"written under {args.checkpoint_dir}")
    else:
        res = mine(g, cfg)

    print(f"[mine] done in {res.elapsed_s:.2f}s"
          f"{' (TIMED OUT)' if res.timed_out else ''}")
    print(f"[mine] frequent patterns: {len(res.frequent)}  "
          f"searched: {res.searched}  peak device bytes: "
          f"{res.peak_device_bytes / 2**20:.1f} MiB")
    if res.health.degraded:
        print(f"[mine] health: {res.health.to_dict()['counts']} — results "
              f"are exact; see --json health.events for detail")
    for lvl, st in res.per_level.items():
        pretty = {k: (round(v, 3) if isinstance(v, float) else v)
                  for k, v in st.items()
                  if k != "block_peaks"}  # long per-block list; JSON only
        print(f"[mine]   level {lvl}: {pretty}")
    for pat, sup in res.frequent[:10]:
        tau = tau_threshold(args.sigma, args.lam, pat.k)
        print(f"[mine]   k={pat.k} sup={sup} (tau={tau}) "
              f"labels={pat.labels.tolist()} edges={pat.edges()}")
    if len(res.frequent) > 10:
        print(f"[mine]   … and {len(res.frequent) - 10} more")

    # warm-start future pricing: fold the measured escalation fraction of
    # this run's sampled levels into the calibration file (schema 3) —
    # the planner's `esc_prior()` reads it back instead of the built-in
    # ESCALATION_PRIOR constant
    samp = [v["sampled"] for v in res.per_level.values()
            if isinstance(v.get("sampled"), dict)
            and not v["sampled"].get("exact", False)]
    decided = sum(int(d.get("escalated", 0)) + int(d.get("pruned", 0))
                  for d in samp)
    if decided > 0 and not res.timed_out:
        from repro.core.planner import persist_escalation_fraction

        measured = sum(int(d.get("escalated", 0)) for d in samp) / decided
        where = persist_escalation_fraction(measured, path=args.calibration)
        if where:
            print(f"[mine] calibration: measured escalation fraction "
                  f"{measured:.3f} folded into {where}")

    if args.json:
        out = {
            "dataset": args.dataset, "scale": args.scale,
            "sigma": args.sigma, "lam": args.lam, "metric": args.metric,
            "generation": args.generation, "execution": args.execution,
            "elapsed_s": res.elapsed_s, "timed_out": res.timed_out,
            "n_frequent": len(res.frequent), "searched": res.searched,
            "peak_device_bytes": res.peak_device_bytes,
            "dispatches": sum(int(v.get("dispatches", 0))
                              for v in res.per_level.values()),
            # sampled plane: escalations across levels (per-level detail —
            # sample fraction, CI width, pruned count — sits in each
            # per_level[...]["sampled"] dict)
            "escalated": sum(int(v.get("sampled", {}).get("escalated", 0))
                             for v in res.per_level.values()),
            "estimated_patterns": sum(1 for st in res.stats if st.estimated),
            # every recovery/fallback/retry the run performed (see
            # core/health.py and README "Run health"); deliberately NOT
            # part of the resume bit-identity contract — a resumed run
            # records the recoveries the uninterrupted oracle never needed
            "health": res.health.to_dict(),
            "per_level": {str(k): v for k, v in res.per_level.items()},
            # deterministic digest of the mined set: (k, support) pairs in
            # result order — what the CI resume-smoke diffs against an
            # uninterrupted run
            "frequent": [[p.k, int(s)] for p, s in res.frequent],
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
