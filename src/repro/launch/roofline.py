"""Roofline analysis (deliverable g) — consumes the dry-run JSON records.

Per (arch × shape × mesh):
    compute    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory     = HLO_bytes_per_device / HBM_bw              [s]
    collective = collective_bytes_per_device / link_bw      [s]

cost_analysis() of the SPMD-partitioned executable is *per device*, so the
given formulas' global numerators over (chips × peak) reduce to these.
MODEL_FLOPS (6·N·D etc., analytic, global) / (chips × HLO_FLOPs) measures
how much compiled compute is useful — remat/dispatch waste shows here.

    PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

# TPU v5e targets (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

__all__ = ["analyze_record", "load_records", "roofline_table", "PEAK_FLOPS",
           "HBM_BW", "LINK_BW"]


def load_records(dir_: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(str(Path(dir_) / "*.json"))):
        try:
            recs.append(json.loads(Path(f).read_text()))
        except Exception:
            pass
    return [r for r in recs if isinstance(r, dict) and "arch" in r]


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    # prefer scan-trip-count-corrected costs (see dryrun_cell calibration)
    cost = rec.get("cost_corrected") or rec.get("cost", {})
    colls = rec.get("collectives_corrected") or rec.get("collectives", {})
    flops_dev = cost.get("flops", -1)
    bytes_dev = cost.get("bytes_accessed", -1)
    coll_dev = colls.get("total", 0)
    chips = rec.get("devices", 256)
    if flops_dev is None or flops_dev < 0:
        return None
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = max(bytes_dev, 0) / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = rec.get("model_flops", 0.0)
    hlo_global = flops_dev * chips
    useful = model_flops / hlo_global if hlo_global > 0 else 0.0
    bound = max(terms.values())
    ideal = model_flops / (chips * PEAK_FLOPS)
    frac = ideal / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec.get("mesh"),
        "kind": rec.get("kind"),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "mem_per_device_bytes": rec.get("memory", {}).get(
            "per_device_total_bytes"),
        "compile_s": rec.get("compile_seconds"),
    }


_SUGGEST = {
    "compute": "reduce recompute (remat policy) / push more FLOPs to bf16 MXU tiles",
    "memory": "fuse elementwise chains, shrink activation dtypes, improve layout reuse",
    "collective": "reshard to cut gathers (SP/TP boundaries), overlap via async collectives, compress DP grads",
}


def roofline_table(recs: List[Dict], *, mesh: str = "16x16") -> str:
    rows = [a for r in recs if (a := analyze_record(r)) and a["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        f"### Roofline — mesh {mesh} (per-device terms, v5e: 197 TF/s bf16, "
        f"819 GB/s HBM, 50 GB/s link)",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful/HLO | roofline frac | move-it-down |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{_SUGGEST[r['dominant']]} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    recs = load_records(args.dir)
    print(roofline_table(recs, mesh=args.mesh))
    skipped = [r for r in recs if r.get("status") == "skipped"]
    if skipped:
        print("\nDocumented skips:")
        for r in skipped:
            print(f"  - {r['arch']} × {r['shape']}: {r['reason']}")
    if args.json_out:
        rows = [a for r in recs if (a := analyze_record(r))]
        Path(args.json_out).write_text(json.dumps(rows, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
