import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
    jit(step).lower(abstract inputs).compile()
on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh, recording
  * memory_analysis()  — proves the cell fits per-chip HBM,
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the partitioned HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute).

Usage:
  python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] [--out DIR]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import get_arch, list_archs
from repro.launch.mesh import make_production_mesh, mesh_device_count
from repro.models.sharding import use_rules

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the partitioned HLO.

    HLO after SPMD partitioning is the per-device program, so these are
    per-device bytes moved (the `collective term` numerator).
    `*-start` / `*-done` pairs are counted once (the start op carries the
    shape).
    """
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        rhs = rhs.strip()
        op = None
        for c in _COLLECTIVES:
            if rhs.startswith(c) or re.match(rf"\(?[\w\[\],\s{{}}]*\)?\s*{c}\(", rhs) \
               or f" {c}(" in f" {rhs}" or rhs.split("(")[0].strip().startswith(c):
                op = c
                break
        if op is None:
            continue
        head = rhs.split("(")[0]
        if head.strip().endswith("-done"):
            continue  # counted at -start
        # result types live on the lhs for HLO text: "%name = TYPE op(...)"
        # but jax prints "name = TYPE op(...)"; TYPE tokens precede op name in rhs?
        # In XLA text: "%x = f32[8,128]{1,0} all-reduce(...)" — the type is in
        # rhs before the op name. Extract types from rhs up to the op name.
        type_part = rhs.split(op)[0]
        total = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(type_part))
        if total == 0:
            # fallback: look at lhs (some printers place the type there)
            total = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(lhs))
        out[op] += total
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _filter_spec(spec: P, mesh: Mesh) -> P:
    names = set(mesh.axis_names)
    parts = []
    for part in spec:
        if part is None:
            parts.append(None)
        elif isinstance(part, (tuple, list)):
            kept = tuple(p for p in part if p in names)
            parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            parts.append(part if part in names else None)
    return P(*parts)


def _axis_size(mesh: Mesh, part) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(part, (tuple, list)):
        n = 1
        for p in part:
            n *= sizes.get(p, 1)
        return n
    return sizes.get(part, 1)


def _fix_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """If a spec axis doesn't divide its dim, relocate it to the last dim
    that does (e.g. mixtral's 8 experts on a 16-way model axis → shard the
    expert FFN dim instead: EP degrades to within-expert TP)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, part) in enumerate(zip(shape, parts)):
        if part is None or dim % _axis_size(mesh, part) == 0:
            continue
        parts[i] = None
        for j in reversed(range(len(shape))):
            if j != i and parts[j] is None and shape[j] % _axis_size(mesh, part) == 0 \
               and shape[j] >= _axis_size(mesh, part):
                parts[j] = part
                break
    return P(*parts)


def _shard(tree_specs, mesh: Mesh, abstract_tree=None):
    if abstract_tree is None:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, _filter_spec(s, mesh)),
            tree_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
    specs_flat, treedef = jax.tree_util.tree_flatten(
        tree_specs, is_leaf=lambda x: isinstance(x, P))
    abs_flat = treedef.flatten_up_to(abstract_tree)
    out = [
        NamedSharding(mesh, _fix_divisibility(
            _filter_spec(s, mesh), tuple(a.shape), mesh))
        for s, a in zip(specs_flat, abs_flat)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def _opt_pspecs_zero1(arch, shape: str, mesh: Mesh):
    """ZeRO-1: AdamW moments additionally sharded along the data axis."""
    from repro.train.optimizer import AdamWState, zero1_specs

    ps = arch.param_pspecs(shape)
    pabs = arch.abstract_params(shape)
    mom = zero1_specs(ps, pabs, data_axes=("data",), mesh=mesh)
    return AdamWState(step=P(), mu=mom, nu=mom)


def _measure(arch, shape: str, mesh: Mesh, *, donate: bool = True,
             zero1: bool = True, save_hlo: Optional[Path] = None
             ) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh); raw measurement record."""
    kind = arch.shapes()[shape].kind
    step = arch.step_fn(shape)
    t0 = time.monotonic()

    params_abs = arch.abstract_params(shape)
    param_sh = _shard(arch.param_pspecs(shape), mesh, params_abs)
    inputs = arch.input_specs(shape)
    input_sh = _shard(arch.input_pspecs(shape), mesh, inputs)

    args: List[Any] = [params_abs]
    shardings: List[Any] = [param_sh]
    opt_sh = None
    if kind == "train":
        opt_abs = arch.abstract_opt(shape)
        opt_specs = (_opt_pspecs_zero1(arch, shape, mesh) if zero1
                     else arch.opt_pspecs(shape))
        opt_sh = _shard(opt_specs, mesh, opt_abs)
        args.append(opt_abs)
        shardings.append(opt_sh)
    for key, spec in inputs.items():
        args.append(spec)
        shardings.append(input_sh[key])

    if kind == "train":
        out_shardings = (NamedSharding(mesh, P()), param_sh, opt_sh)
        donate_argnums = (0, 1) if donate else ()
    elif kind == "decode":
        out_shardings = (NamedSharding(mesh, P()), input_sh["cache"])
        donate_argnums = (1,) if donate else ()
    else:
        out_shardings = None
        donate_argnums = ()

    with use_rules(mesh):
        jitted = jax.jit(step, in_shardings=tuple(shardings),
                         out_shardings=out_shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    compile_s = time.monotonic() - t0
    record: Dict[str, Any] = {
        "kind": kind, "status": "ok",
        "devices": mesh_device_count(mesh),
        "compile_seconds": round(compile_s, 1),
    }

    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
        args_b = record["memory"].get("argument_size_in_bytes", 0)
        alias_b = record["memory"].get("alias_size_in_bytes", 0)
        out_b = record["memory"].get("output_size_in_bytes", 0)
        tmp_b = record["memory"].get("temp_size_in_bytes", 0)
        record["memory"]["per_device_total_bytes"] = (
            args_b + tmp_b + max(out_b - alias_b, 0))
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": repr(e)}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["cost"] = {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "transcendentals": float(cost.get("transcendentals", -1)),
        }
    except Exception as e:  # pragma: no cover
        record["cost"] = {"error": repr(e)}

    hlo = compiled.as_text()
    record["collectives"] = collective_bytes_from_hlo(hlo)
    if save_hlo:
        save_hlo.parent.mkdir(parents=True, exist_ok=True)
        save_hlo.write_text(hlo)
        record["hlo_path"] = str(save_hlo)
    return record


def dryrun_cell(arch_name: str, shape: str, *, multi_pod: bool = False,
                save_hlo: Optional[Path] = None, donate: bool = True,
                calibrate: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; §Dry-run/§Roofline record.

    For scanned LM stacks, a second *unrolled 2-step* lowering calibrates
    the while-loop once-counting of XLA cost analysis (see LMArch
    .calibration_arch): body = U2 − S per metric, corrected = S +
    (n_steps − 1) × body, applied to flops / bytes / transcendentals /
    per-collective bytes.  Peak memory is NOT corrected (loops reuse
    buffers; the scanned number is the true one).
    """
    arch = get_arch(arch_name)
    skip = arch.skip_reason(shape)
    base = {"arch": arch_name, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "multi_pod": multi_pod}
    if skip:
        return {**base, "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {**base, **_measure(arch, shape, mesh, donate=donate,
                                 save_hlo=save_hlo)}
    record["model_flops"] = float(arch.model_flops(shape))

    if calibrate and hasattr(arch, "calibration_arch"):
        try:
            cal = _measure(arch.calibration_arch(), shape, mesh,
                           donate=donate)
            n = arch.scan_steps
            record["calibration"] = {
                "u2_cost": cal.get("cost"),
                "u2_collectives": cal.get("collectives"),
                "scan_steps": n,
            }

            def corr(s_val, u_val):
                body = max(u_val - s_val, 0.0)
                return s_val + (n - 1) * body

            c_s, c_u = record.get("cost", {}), cal.get("cost", {})
            if "flops" in c_s and "flops" in c_u:
                record["cost_corrected"] = {
                    k: corr(c_s[k], c_u[k])
                    for k in ("flops", "bytes_accessed", "transcendentals")
                    if c_s.get(k, -1) >= 0 and c_u.get(k, -1) >= 0
                }
            col_s, col_u = record.get("collectives", {}), cal.get("collectives", {})
            record["collectives_corrected"] = {
                k: corr(float(col_s.get(k, 0)), float(col_u.get(k, 0)))
                for k in _COLLECTIVES + ("total",)
            }
        except Exception as e:  # calibration is best-effort
            record["calibration"] = {"error": repr(e)}
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = list(arch.shapes()) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch_name}__{shape}__{'mp' if mp else 'sp'}"
                try:
                    rec = dryrun_cell(
                        arch_name, shape, multi_pod=mp,
                        save_hlo=(out_dir / f"{tag}.hlo.txt")
                        if args.save_hlo else None)
                except Exception as e:
                    rec = {"arch": arch_name, "shape": shape, "multi_pod": mp,
                           "status": "FAILED", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                mem = rec.get("memory", {}).get("per_device_total_bytes")
                mem_s = f" mem/dev={mem/2**30:.2f}GiB" if mem else ""
                coll = rec.get("collectives", {}).get("total")
                coll_s = f" coll/dev={coll/2**20:.1f}MiB" if coll is not None else ""
                print(f"[dryrun] {tag}: {status}{mem_s}{coll_s}", flush=True)
                if status == "FAILED":
                    print(rec.get("error", ""), flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
