import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, before any jax import (device count locks on first init)

"""Dry-run for the paper's own workload: one distributed mining step
(match_block per device + global Luby mIS rounds) lowered + compiled on the
production meshes.  Proves the technique's collective pattern (per-round
all-reduce(min) over the |V| priority array + bitmap psum) partitions.

    PYTHONPATH=src python -m repro.launch.dryrun_flexis [--multi-pod]
"""
import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jax_compat

from repro.configs import flexis_paper as FP
from repro.core.graph import DeviceGraph
from repro.core.matcher import MatchConfig
from repro.core import mis as mis_lib
from repro.core.distributed import sharded_mis_step
from repro.core.plan import PatternPlan
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.launch.mesh import make_production_mesh, mesh_device_count


def abstract_graph(n: int, m: int) -> DeviceGraph:
    sds = jax.ShapeDtypeStruct
    return DeviceGraph(
        n=n,
        labels=sds((n,), jnp.int32),
        out_indptr=sds((n + 1,), jnp.int32),
        out_indices=sds((m,), jnp.int32),
        in_indptr=sds((n + 1,), jnp.int32),
        in_indices=sds((m,), jnp.int32),
    )


def abstract_plan(k: int) -> PatternPlan:
    sds = jax.ShapeDtypeStruct
    return PatternPlan(
        k=k,
        root_label=sds((), jnp.int32),
        root_min_out=sds((), jnp.int32),
        root_min_in=sds((), jnp.int32),
        anchor_pos=sds((k,), jnp.int32),
        anchor_out=sds((k,), jnp.bool_),
        cand_label=sds((k,), jnp.int32),
        min_out=sds((k,), jnp.int32),
        min_in=sds((k,), jnp.int32),
        check_out=sds((k, k), jnp.bool_),
        check_in=sds((k, k), jnp.bool_),
        order=tuple(range(k)),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rc = 0
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        ndev = mesh_device_count(mesh)
        axis = "roots"
        flat = jax_compat.make_raw_mesh(mesh.devices.reshape(-1), (axis,))
        cfg = MatchConfig(cap=FP.MATCH_CAP, root_block=FP.ROOT_BLOCK,
                          chunk=FP.CHUNK, max_chunks=FP.MAX_CHUNKS,
                          bisect_iters=FP.BISECT_ITERS)
        n, m, k = FP.N_VERTICES, FP.N_EDGES, FP.PATTERN_K
        g = abstract_graph(n, m)
        plan = abstract_plan(k)
        starts = jax.ShapeDtypeStruct((ndev,), jnp.int32)
        bitmap = jax.ShapeDtypeStruct(((n + 31) // 32,), jnp.uint32)
        count = jax.ShapeDtypeStruct((), jnp.int32)
        tau = jax.ShapeDtypeStruct((), jnp.int32)

        def step(g_, plan_, starts_, bitmap_, count_, tau_):
            return sharded_mis_step(g_, plan_, starts_, bitmap_, count_,
                                    tau_, cfg=cfg, k=k, n=n, axis=axis,
                                    mesh=flat)

        t0 = time.monotonic()
        with flat:
            lowered = jax.jit(
                step,
                in_shardings=(
                    jax.tree_util.tree_map(lambda _: NamedSharding(flat, P()), g),
                    jax.tree_util.tree_map(lambda _: NamedSharding(flat, P()), plan),
                    NamedSharding(flat, P(axis)),
                    NamedSharding(flat, P()),
                    NamedSharding(flat, P()),
                    NamedSharding(flat, P()),
                ),
            ).lower(g, plan, starts, bitmap, count, tau)
            compiled = lowered.compile()
        dt = time.monotonic() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        colls = collective_bytes_from_hlo(compiled.as_text())
        rec = {
            "arch": "flexis-mining", "shape": f"mico_k{k}",
            "mesh": "2x16x16" if mp else "16x16", "multi_pod": mp,
            "kind": "mine", "status": "ok", "devices": ndev,
            "compile_seconds": round(dt, 1),
            "memory": {
                "argument_size_in_bytes": int(mem.argument_size_in_bytes),
                "temp_size_in_bytes": int(mem.temp_size_in_bytes),
                "per_device_total_bytes": int(mem.argument_size_in_bytes
                                              + mem.temp_size_in_bytes),
            },
            "cost": {"flops": float(cost.get("flops", -1)),
                     "bytes_accessed": float(cost.get("bytes accessed", -1))},
            "collectives": colls,
            # one step ≈ cap·chunks·k gathers + bisect work; report matcher
            # work as "model flops" proxy: candidate checks × ops
            "model_flops": float(ndev * cfg.cap * cfg.chunk * cfg.max_chunks
                                 * k * (2 * cfg.bisect_iters + 8)),
        }
        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        tag = f"flexis-mining__mico_k{k}__{'mp' if mp else 'sp'}"
        (out / f"{tag}.json").write_text(json.dumps(rec, indent=2))
        print(f"[dryrun-flexis] {tag}: ok "
              f"mem/dev={rec['memory']['per_device_total_bytes']/2**30:.2f}GiB "
              f"coll/dev={colls['total']/2**20:.1f}MiB "
              f"(compile {dt:.0f}s)", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
