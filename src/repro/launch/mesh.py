"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS before
any jax initialization)."""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro import jax_compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_mesh_for_devices(n: Optional[int] = None,
                          model_parallel: int = 1) -> Mesh:
    """Small-scale mesh for local runs/tests: (n/model, model)."""
    n = n if n is not None else len(jax.devices())
    assert n % model_parallel == 0
    return jax_compat.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"))


def mesh_device_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
