"""Training launcher — end-to-end driver (deliverable b).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

Full production flow: mesh → sharded params/opt → data pipeline →
jit'd train step (loss+grad+AdamW, remat, bf16) → async checkpoints +
heartbeat + straggler guard + auto-resume.  `--reduced` runs the smoke
config end-to-end on CPU; the same code path drives the full config on a
real pod (the dry-run proves those shardings compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.synthetic import dlrm_batches, token_stream
from repro.launch.mesh import make_mesh_for_devices
from repro.models.sharding import use_rules
from repro.train import checkpoint as ckpt
from repro.train.elastic import HeartbeatFile, StepGuard, StragglerTimeout
from repro.train.optimizer import adamw_init


def train_lm(arch, args) -> int:
    cfg = arch.reduced_cfg if args.reduced else arch.cfg
    mesh = make_mesh_for_devices(model_parallel=args.model_parallel)
    from repro.models.transformer import transformer_init
    from repro.train.optimizer import AdamWConfig, adamw_update
    from repro.models.transformer import lm_loss

    opt_cfg = dataclasses.replace(arch.opt, total_steps=args.steps,
                                  warmup_steps=max(args.steps // 20, 1))

    def step_fn(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, targets)
        params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
        return loss, params, opt_state

    with use_rules(mesh):
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        ckpt_dir = Path(args.ckpt_dir)
        hb = HeartbeatFile(ckpt_dir / "heartbeat")

        params_abs = jax.eval_shape(
            lambda: transformer_init(jax.random.key(args.seed), cfg))
        start_step = 0
        extra = {}
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), extra, got = ckpt.restore(
                ckpt_dir, (params_abs, jax.eval_shape(adamw_init, params_abs)))
            start_step = got + 1
            print(f"[train] resumed from step {got}")
        else:
            params = transformer_init(jax.random.key(args.seed), cfg)
            opt_state = adamw_init(params)

        stream = token_stream(cfg.vocab, args.batch, args.seq,
                              seed=args.seed,
                              start_step=int(extra.get("data_step", start_step)))
        t0 = time.monotonic()
        losses = []
        for step in range(start_step, args.steps):
            tokens, targets = next(stream)
            try:
                with StepGuard(args.step_budget_s):
                    loss, params, opt_state = jit_step(
                        params, opt_state, jnp.asarray(tokens),
                        jnp.asarray(targets))
                    loss = float(loss)
            except StragglerTimeout:
                print(f"[train] step {step} straggled; checkpoint-restart")
                ckpt.save(ckpt_dir, step - 1, (params, opt_state),
                          extra={"data_step": step})
                return 75  # conventional tempfail → scheduler restarts us
            losses.append(loss)
            hb.beat(step)
            if step % args.ckpt_every == args.ckpt_every - 1:
                ckpt.save_async(ckpt_dir, step, (params, opt_state),
                                extra={"data_step": step + 1})
            if step % args.log_every == 0:
                dt = time.monotonic() - t0
                print(f"[train] step={step} loss={loss:.4f} "
                      f"({dt / max(step - start_step + 1, 1):.2f}s/step)",
                      flush=True)
        ckpt.wait_pending()
        ckpt.save(ckpt_dir, args.steps - 1, (params, opt_state),
                  extra={"data_step": args.steps})
        print(f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}")
        return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--step-budget-s", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for GNN/recsys")
    return train_lm(arch, args)


if __name__ == "__main__":
    sys.exit(main())
