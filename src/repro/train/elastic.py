"""Elastic scaling + failure handling for the host training loop.

Design (1000+-node posture, DESIGN.md §4):

  * Checkpoints are mesh-shape-agnostic (full logical arrays), so recovery
    after losing nodes is: build the largest feasible mesh from surviving
    devices (`best_mesh`), `restore(..., shardings=new)` — no format change.
  * The step loop runs under `StepGuard`: a wall-clock budget per step; a
    straggling/hung step raises `StragglerTimeout` so the runner can
    checkpoint-restart (in a real deployment, after excluding the slow
    host).  Inside a step, work is fixed-shape (frontier caps, padded
    blocks), which bounds skew structurally.
  * `HeartbeatFile` is the cross-host liveness primitive a cluster agent
    watches (mtime stale ⇒ kill + reschedule).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from pathlib import Path
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro import jax_compat

__all__ = ["best_mesh", "StragglerTimeout", "StepGuard", "HeartbeatFile",
           "resume_or_init"]


class StragglerTimeout(RuntimeError):
    pass


def best_mesh(n_devices: Optional[int] = None, *,
              prefer_model: int = 16) -> Mesh:
    """Largest (data, model) mesh over surviving devices: model axis is the
    largest power-of-two divisor ≤ prefer_model, data gets the rest."""
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    model = 1
    while model * 2 <= prefer_model and n % (model * 2) == 0:
        model *= 2
    data = n // model
    return jax_compat.make_mesh(
        (data, model), ("data", "model"), devices=np.array(devs[:n]))


@dataclasses.dataclass
class StepGuard:
    """Raise StragglerTimeout if a step exceeds `budget_s` (SIGALRM-based;
    main thread only — exactly where the host loop lives)."""

    budget_s: float

    def __enter__(self):
        if self.budget_s and hasattr(signal, "SIGALRM"):
            self._old = signal.signal(signal.SIGALRM, self._fire)
            signal.setitimer(signal.ITIMER_REAL, self.budget_s)
        return self

    @staticmethod
    def _fire(signum, frame):
        raise StragglerTimeout("step exceeded wall-clock budget")

    def __exit__(self, *exc):
        if self.budget_s and hasattr(signal, "SIGALRM"):
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, self._old)
        return False


class HeartbeatFile:
    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int):
        self.path.write_text(f"{step} {time.time()}\n")

    def age_s(self) -> Optional[float]:
        if not self.path.exists():
            return None
        return time.time() - self.path.stat().st_mtime


def resume_or_init(ckpt_dir, init_fn, abstract_tree, shardings=None):
    """Restore the latest committed checkpoint onto the (possibly new) mesh,
    or initialize fresh. Returns (state, extra, start_step)."""
    from . import checkpoint as ckpt

    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return init_fn(), {}, 0
    state, extra, step = ckpt.restore(ckpt_dir, abstract_tree, step=step,
                                      shardings=shardings)
    return state, extra, step + 1
