"""Gradient compression for the data-parallel all-reduce.

int8 quantize → all-reduce → dequantize, with per-tensor scales kept fp32.
At 1000+ nodes the DP gradient reduction is wire-bound; 4× fewer bytes on
the pod-interconnect axis buys near-linear speedup on that term (recorded
in EXPERIMENTS.md §Perf).  Error feedback (residual carrying) keeps the
quantization noise unbiased across steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import jax_compat

__all__ = ["CompressionState", "compression_init", "compress_tree",
           "decompress_tree", "compressed_psum"]

Params = Any


class CompressionState(NamedTuple):
    residual: Params  # error-feedback accumulator


def compression_init(grads: Params) -> CompressionState:
    return CompressionState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads))


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Params, state: Optional[CompressionState] = None):
    """→ (quantized tree, scales tree, new residual state)."""
    if state is not None:
        grads = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state.residual)
    qs = jax.tree_util.tree_map(_quantize, grads)
    q_tree = jax.tree_util.tree_map(lambda t: t[0], qs,
                                    is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree_util.tree_map(lambda t: t[1], qs,
                                    is_leaf=lambda x: isinstance(x, tuple))
    if state is not None:
        residual = jax.tree_util.tree_map(
            lambda g, q, s: g - _dequantize(q, s), grads, q_tree, s_tree)
        state = CompressionState(residual=residual)
    return q_tree, s_tree, state


def decompress_tree(q_tree: Params, s_tree: Params) -> Params:
    return jax.tree_util.tree_map(_dequantize, q_tree, s_tree)


def compressed_psum(grads: Params, axis: str,
                    state: Optional[CompressionState] = None):
    """Inside shard_map: int8 all-reduce of the gradient tree.

    Sums int8 payloads in int32 (no overflow for ≤2^23 participants) and
    averages the per-device scales — an unbiased mean-of-quantized estimate.
    """
    q, s, state = compress_tree(grads, state)
    q32 = jax.tree_util.tree_map(lambda x: x.astype(jnp.int32), q)
    q_sum = jax.lax.psum(q32, axis)
    s_mean = jax.lax.pmean(s, axis)
    n = jax_compat.axis_size(axis)
    out = jax.tree_util.tree_map(
        lambda qq, ss: qq.astype(jnp.float32) * ss / n, q_sum, s_mean)
    return out, state
