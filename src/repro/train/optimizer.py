"""Optimizers — AdamW (+ cosine/linear-warmup schedule), pure functional.

Optimizer state shards like its parameters by default; ZeRO-1 style
data-axis sharding of the moments is opt-in via `zero1_specs` (used by the
launcher when the mesh has a data axis and the param's leading dim divides).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Params
    nu: Params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat, treedef = jax.tree_util.tree_flatten(params)
    gflat = treedef.flatten_up_to(grads)
    mflat = treedef.flatten_up_to(state.mu)
    vflat = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def zero1_specs(param_specs, abstract_params, data_axes=("data",), mesh=None):
    """ZeRO-1: shard optimizer moments along the first *unsharded, divisible*
    dim over the data axis (params keep their own sharding)."""
    if mesh is None:
        return param_specs
    dsize = 1
    for ax in data_axes:
        dsize *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(ax, 1)

    def one(spec: P, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, part) in enumerate(zip(leaf.shape, parts)):
            if part is None and dim % max(dsize, 1) == 0 and dim >= dsize > 1:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(one, param_specs, abstract_params)
