"""Sharded, atomic, mesh-shape-agnostic checkpoints (fault tolerance core).

Layout (one directory per step):
    <root>/step_000123/
        manifest.json         # tree structure, shapes, dtypes, data state
        arr_00000.npy …       # one file per leaf (full logical array)
        COMMIT                # written last — a step without COMMIT is junk

Guarantees:
  * atomic: writes go to step_XXXX.tmp/, fsync'd, then rename + COMMIT —
    a crash mid-save never corrupts the latest good checkpoint;
  * elastic: leaves are saved as *full logical arrays* so a restore may use
    a different mesh shape (re-sharding happens on load via device_put);
  * resumable data pipeline: the manifest carries opaque `extra` state
    (data-pipeline cursor, rng key, mining super-block index);
  * retention: keep_last prunes old steps after a successful COMMIT.

An async flavor (`save_async`) offloads the host write to a thread so the
next step's compute overlaps the checkpoint I/O.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_PENDING: list = []


def _tree_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str | os.PathLike, step: int, tree, *,
         extra: Optional[Dict[str, Any]] = None, keep_last: int = 3) -> Path:
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _tree_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "time": time.time(),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i:05d}.npy", arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync directory contents before commit
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (final / "COMMIT").write_text("ok")

    # retention
    steps = sorted(p for p in root.glob("step_????????")
                   if (p / "COMMIT").exists())
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def save_async(root, step, tree, *, extra=None, keep_last: int = 3):
    """Snapshot to host memory synchronously, write to disk in a thread."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    snapshot = jax.tree_util.tree_unflatten(treedef, host_leaves)

    t = threading.Thread(
        target=save, args=(root, step, snapshot),
        kwargs=dict(extra=extra, keep_last=keep_last), daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def latest_step(root) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted(p for p in root.glob("step_????????")
                   if (p / "COMMIT").exists())
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(root, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict[str, Any], int]:
    """Restore into the structure of `tree_like` (shapes must match).

    `shardings`: optional pytree of NamedSharding — leaves are device_put
    with them (elastic re-mesh happens here: the stored arrays are logical).
    Returns (tree, extra, step).
    """
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT (partial write?)")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs tree {len(leaves_like)}"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_like))
    for i, (like, sh) in enumerate(zip(leaves_like, sh_leaves)):
        arr = np.load(d / f"arr_{i:05d}.npy")
        want = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: shape {arr.shape} != expected {want}")
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest.get("extra", {}), step
