"""Sharded, atomic, mesh-shape-agnostic checkpoints (fault tolerance core).

Layout (one directory per step):
    <root>/step_000123/
        manifest.json         # tree structure, shapes, dtypes, CRCs, extra
        arr_00000.npy …       # one file per leaf (full logical array)
        COMMIT                # written last — a step without COMMIT is junk

Guarantees:
  * atomic: writes go to step_XXXX.tmp/, fsync'd, then rename + COMMIT —
    a crash mid-save never corrupts the latest good checkpoint;
  * verified: the manifest carries a CRC-32 per leaf (format v2); `restore`
    checks every array against it and raises `CorruptCheckpointError` on
    silent bit-rot instead of handing back garbage (v1 manifests without
    CRCs still restore, unverified);
  * self-healing callers: `committed_steps` + per-step `restore` let a
    caller walk the retained COMMIT chain newest→oldest until a step
    verifies (the session runtime does exactly this — see
    `repro.runtime.resume.load_session`);
  * retried: transient I/O errors during a save (`EIO`, `ENOSPC`, `EAGAIN`,
    `EINTR`) are retried with exponential backoff before giving up — the
    tmp-dir protocol makes a retried attempt indistinguishable from a
    first one;
  * elastic: leaves are saved as *full logical arrays* so a restore may use
    a different mesh shape (re-sharding happens on load via device_put);
  * resumable data pipeline: the manifest carries opaque `extra` state
    (data-pipeline cursor, rng key, mining level/group/super-block cursor —
    the mining session runtime keeps its whole host-side state here);
  * validated `extra`: `extra` must round-trip through JSON — `save`
    rejects non-serializable state up front (fail fast on the host, never
    a half-written manifest) and normalizes it through an encode/decode
    cycle so save-time and restore-time values are identical (tuples
    become lists *before* the write, not after the crash);
  * retention: keep_last prunes old steps after a successful COMMIT, and
    stale ``step_*.tmp`` directories abandoned by a crashed writer are
    swept on the next save — the sweep TTL is configurable
    (``stale_tmp_s`` / ``$REPRO_STALE_TMP_S``) and *always* excludes tmp
    dirs this process is currently writing, so an aggressive TTL can
    never race an in-flight `save_async`.

An async flavor (`save_async`) offloads the host write to a thread so the
next step's compute overlaps the checkpoint I/O.  Background failures are
never swallowed: each worker records its exception, and the first one is
re-raised from `wait_pending()` or from the next `save`/`save_async` call.

Fault injection: the write path fires named `repro.runtime.faults` points
(``save.io``, ``save.array_write``, ``save.manifest``, ``save.pre_commit``,
``save.committed``) — no-ops unless a `FaultPlan` is installed — which is
how the chaos tests prove every guarantee above deterministically.
"""
from __future__ import annotations

import errno
import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "committed_steps",
           "wait_pending", "validate_extra", "CorruptCheckpointError",
           "TRANSIENT_ERRNOS", "DEFAULT_SAVE_RETRIES",
           "DEFAULT_RETRY_BACKOFF_S", "STALE_TMP_ENV"]

# format 2 = format 1 + per-leaf "crc32" in manifest["leaves"] entries
FORMAT_VERSION = 2

# a step_*.tmp untouched for this long was abandoned by a crashed writer
# (a live save_async thread is still appending/fsyncing well within this);
# override per-call via save(stale_tmp_s=...) or globally via the env var
_STALE_TMP_S = 60.0
STALE_TMP_ENV = "REPRO_STALE_TMP_S"

# save I/O errors worth retrying: transient device/FS conditions that a
# backoff can outlive (a full disk is often a *briefly* full disk when a
# retention sweep or log rotation runs beside the writer)
TRANSIENT_ERRNOS = frozenset({errno.EIO, errno.ENOSPC, errno.EAGAIN,
                              errno.EINTR})
DEFAULT_SAVE_RETRIES = 2
DEFAULT_RETRY_BACKOFF_S = 0.05

# in-flight background saves: (thread, error_slot) pairs.  error_slot is a
# one-element list the worker fills on failure — `wait_pending` and the
# next `save`/`save_async` re-raise the first collected error instead of
# letting it die with the daemon thread.
_PENDING: List[Tuple[threading.Thread, list]] = []
_PENDING_LOCK = threading.Lock()

# tmp dirs this process is writing right now — the stale sweep never
# touches them, whatever the TTL says
_ACTIVE_TMP: set = set()
_ACTIVE_LOCK = threading.Lock()


class CorruptCheckpointError(ValueError):
    """A committed step failed verification (CRC mismatch / bad manifest)."""


def _fire(point: str, **ctx) -> None:
    """Fault-injection hook (lazy import: train/ must not require runtime/
    at import time).  One function call + None check when no plan is
    installed."""
    try:
        from repro.runtime import faults
    except ImportError:  # pragma: no cover - runtime package always ships
        return
    faults.fire(point, **ctx)


def validate_extra(extra: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    """Normalize + validate the opaque `extra` manifest slot.

    Returns the JSON round-trip of ``extra`` (so the caller sees exactly
    what a restore will see), raising a `TypeError` naming the offending
    key when any value is not JSON-serializable.  Generalized for cursor
    state beyond the original flat data-pipeline dict: arbitrarily nested
    session cursors (level / pattern-group / super-block indices) are fine;
    arrays and other device state belong in the pytree, not here.
    """
    return json.loads(_ensure_json_extra(extra))


def _ensure_json_extra(extra: Optional[Dict[str, Any]]) -> str:
    """Serialize-validate ``extra`` once; returns the JSON text.

    `save` uses this directly — the manifest write re-normalizes anyway, so
    the extra `loads` of `validate_extra` would be pure overhead on the
    snapshot hot path (sessions may cut a snapshot per root block).
    """
    if extra is None:
        return "{}"
    if not isinstance(extra, dict):
        raise TypeError(f"extra must be a dict, got {type(extra).__name__}")
    try:
        return json.dumps(extra)
    except TypeError:
        for key, value in extra.items():
            try:
                json.dumps(value)
            except TypeError as e:
                raise TypeError(
                    f"extra[{key!r}] is not JSON-serializable: {e}") from e
        raise


def _tree_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _stale_ttl(stale_tmp_s: Optional[float]) -> float:
    if stale_tmp_s is not None:
        return float(stale_tmp_s)
    env = os.environ.get(STALE_TMP_ENV)
    if env:
        try:
            return float(env)
        except ValueError:
            pass
    return _STALE_TMP_S


def _raise_pending_errors() -> None:
    """Re-raise the first error a *finished* background save collected.

    Non-blocking: still-running writers are left alone (they are checked
    again at the next call or at `wait_pending`)."""
    with _PENDING_LOCK:
        done = [(t, e) for t, e in _PENDING if not t.is_alive()]
        for entry in done:
            _PENDING.remove(entry)
    errs = [e[0] for _, e in done if e]
    if errs:
        raise errs[0]


def save(root: str | os.PathLike, step: int, tree, *,
         extra: Optional[Dict[str, Any]] = None, keep_last: int = 3,
         retries: int = DEFAULT_SAVE_RETRIES,
         retry_backoff_s: float = DEFAULT_RETRY_BACKOFF_S,
         stale_tmp_s: Optional[float] = None,
         health=None) -> Path:
    """Atomically persist ``tree`` (+ ``extra``) as step ``step``.

    Transient I/O errors (`TRANSIENT_ERRNOS`) are retried up to ``retries``
    times with exponential backoff starting at ``retry_backoff_s``; each
    retry is recorded on ``health`` (a `repro.core.health.RunHealth`) when
    given.  Also surfaces (re-raises) any error a previous `save_async`
    worker collected.
    """
    _ensure_json_extra(extra)  # fail fast, before any disk write
    _raise_pending_errors()
    for attempt in range(retries + 1):
        try:
            return _save_once(root, step, tree, extra=extra,
                              keep_last=keep_last, stale_tmp_s=stale_tmp_s)
        except OSError as e:
            if e.errno not in TRANSIENT_ERRNOS or attempt == retries:
                raise
            if health is not None:
                health.record(
                    "save_retry",
                    f"attempt {attempt + 1}/{retries + 1} hit "
                    f"{errno.errorcode.get(e.errno, e.errno)}: {e}",
                    step=step)
            time.sleep(retry_backoff_s * (2 ** attempt))
    raise AssertionError("unreachable")


def _save_once(root, step: int, tree, *, extra, keep_last: int,
               stale_tmp_s: Optional[float]) -> Path:
    extra = extra or {}
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    with _ACTIVE_LOCK:
        _ACTIVE_TMP.add(tmp)
    try:
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        _fire("save.io", step=step)

        leaves, treedef = _tree_paths(tree)
        manifest = {
            "format_version": FORMAT_VERSION,
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "extra": extra,
            "time": time.time(),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fpath = tmp / f"arr_{i:05d}.npy"
            np.save(fpath, arr)
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
            _fire("save.array_write", path=fpath, step=step)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        _fire("save.manifest", path=tmp / "manifest.json", step=step)
        # fsync directory contents before commit
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _fire("save.pre_commit", step=step)
        (final / "COMMIT").write_text("ok")
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE_TMP.discard(tmp)
    _fire("save.committed", path=final, step=step)

    # retention — committed steps beyond keep_last, plus any stale tmp dirs
    # abandoned by a writer that crashed before its rename (ours was either
    # renamed away above or never existed at this point; other *live* tmp
    # dirs of this process are excluded via _ACTIVE_TMP regardless of age)
    steps = sorted(p for p in root.glob("step_????????")
                   if (p / "COMMIT").exists())
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    ttl = _stale_ttl(stale_tmp_s)
    with _ACTIVE_LOCK:
        active = set(_ACTIVE_TMP)
    for junk in root.glob("step_????????.tmp"):
        if junk in active:
            continue
        try:  # age-guarded: never race a concurrent writer's fresh tmp
            stale = time.time() - junk.stat().st_mtime > ttl
        except OSError:
            continue
        if stale:
            shutil.rmtree(junk, ignore_errors=True)
    return final


def save_async(root, step, tree, *, extra=None, keep_last: int = 3,
               retries: int = DEFAULT_SAVE_RETRIES,
               retry_backoff_s: float = DEFAULT_RETRY_BACKOFF_S,
               stale_tmp_s: Optional[float] = None, health=None):
    """Snapshot to host memory synchronously, write to disk in a thread.

    Returns the worker thread (join it, or call `wait_pending`).  A worker
    that fails records its exception; `wait_pending` or the next
    `save`/`save_async` re-raises it — background write failures are never
    silently dropped.
    """
    _raise_pending_errors()
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    snapshot = jax.tree_util.tree_unflatten(treedef, host_leaves)

    err: list = []

    def _work():
        try:
            save(root, step, snapshot, extra=extra, keep_last=keep_last,
                 retries=retries, retry_backoff_s=retry_backoff_s,
                 stale_tmp_s=stale_tmp_s, health=health)
        except BaseException as e:  # noqa: BLE001 - collected, not dropped
            err.append(e)

    t = threading.Thread(target=_work, daemon=True)
    t.start()
    with _PENDING_LOCK:
        _PENDING.append((t, err))
    return t


def wait_pending(raise_errors: bool = True) -> List[BaseException]:
    """Join every in-flight background save.

    Re-raises the first collected worker error (``raise_errors=True``,
    default) or returns the list of errors (``raise_errors=False`` — the
    session runtime drains this way and records them in `RunHealth`).
    """
    errs: List[BaseException] = []
    while True:
        with _PENDING_LOCK:
            if not _PENDING:
                break
            t, e = _PENDING.pop()
        t.join()
        errs.extend(e)
    if errs and raise_errors:
        raise errs[0]
    return errs


def committed_steps(root) -> List[int]:
    """All committed step indices under ``root``, ascending."""
    root = Path(root)
    if not root.exists():
        return []
    return sorted(int(p.name.split("_")[1])
                  for p in root.glob("step_????????")
                  if (p / "COMMIT").exists())


def latest_step(root) -> Optional[int]:
    steps = committed_steps(root)
    return steps[-1] if steps else None


def restore(root, tree_like, *, step: Optional[int] = None,
            shardings=None, verify: bool = True
            ) -> Tuple[Any, Dict[str, Any], int]:
    """Restore into the structure of `tree_like` (shapes must match).

    `shardings`: optional pytree of NamedSharding — leaves are device_put
    with them (elastic re-mesh happens here: the stored arrays are logical).
    ``verify`` checks each array against its manifest CRC-32 (format-v2
    checkpoints; v1 manifests without CRCs load unverified) and raises
    `CorruptCheckpointError` on a mismatch.  Returns (tree, extra, step).
    """
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT (partial write?)")
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except ValueError as e:
        raise CorruptCheckpointError(f"{d}: unreadable manifest: {e}") from e
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs tree {len(leaves_like)}"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_like))
    for i, (like, sh) in enumerate(zip(leaves_like, sh_leaves)):
        try:
            arr = np.load(d / f"arr_{i:05d}.npy")
        except (OSError, ValueError) as e:
            raise CorruptCheckpointError(
                f"{d}: leaf {i} unreadable: {e}") from e
        want = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: shape {arr.shape} != expected {want}")
        stored_crc = manifest["leaves"][i].get("crc32")
        if verify and stored_crc is not None:
            got_crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got_crc != stored_crc:
                raise CorruptCheckpointError(
                    f"{d}: leaf {i} CRC mismatch "
                    f"(stored {stored_crc:#010x}, got {got_crc:#010x}) — "
                    f"silent corruption")
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest.get("extra", {}), step
