"""Sharded, atomic, mesh-shape-agnostic checkpoints (fault tolerance core).

Layout (one directory per step):
    <root>/step_000123/
        manifest.json         # tree structure, shapes, dtypes, data state
        arr_00000.npy …       # one file per leaf (full logical array)
        COMMIT                # written last — a step without COMMIT is junk

Guarantees:
  * atomic: writes go to step_XXXX.tmp/, fsync'd, then rename + COMMIT —
    a crash mid-save never corrupts the latest good checkpoint;
  * elastic: leaves are saved as *full logical arrays* so a restore may use
    a different mesh shape (re-sharding happens on load via device_put);
  * resumable data pipeline: the manifest carries opaque `extra` state
    (data-pipeline cursor, rng key, mining level/group/super-block cursor —
    the mining session runtime keeps its whole host-side state here);
  * validated `extra`: `extra` must round-trip through JSON — `save`
    rejects non-serializable state up front (fail fast on the host, never
    a half-written manifest) and normalizes it through an encode/decode
    cycle so save-time and restore-time values are identical (tuples
    become lists *before* the write, not after the crash);
  * retention: keep_last prunes old steps after a successful COMMIT, and
    stale ``step_*.tmp`` directories abandoned by a crashed writer are
    swept on the next save.

An async flavor (`save_async`) offloads the host write to a thread so the
next step's compute overlaps the checkpoint I/O.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending",
           "validate_extra"]

FORMAT_VERSION = 1

# a step_*.tmp untouched for this long was abandoned by a crashed writer
# (a live save_async thread is still appending/fsyncing well within this)
_STALE_TMP_S = 60.0

_PENDING: list = []


def validate_extra(extra: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    """Normalize + validate the opaque `extra` manifest slot.

    Returns the JSON round-trip of ``extra`` (so the caller sees exactly
    what a restore will see), raising a `TypeError` naming the offending
    key when any value is not JSON-serializable.  Generalized for cursor
    state beyond the original flat data-pipeline dict: arbitrarily nested
    session cursors (level / pattern-group / super-block indices) are fine;
    arrays and other device state belong in the pytree, not here.
    """
    return json.loads(_ensure_json_extra(extra))


def _ensure_json_extra(extra: Optional[Dict[str, Any]]) -> str:
    """Serialize-validate ``extra`` once; returns the JSON text.

    `save` uses this directly — the manifest write re-normalizes anyway, so
    the extra `loads` of `validate_extra` would be pure overhead on the
    snapshot hot path (sessions may cut a snapshot per root block).
    """
    if extra is None:
        return "{}"
    if not isinstance(extra, dict):
        raise TypeError(f"extra must be a dict, got {type(extra).__name__}")
    try:
        return json.dumps(extra)
    except TypeError:
        for key, value in extra.items():
            try:
                json.dumps(value)
            except TypeError as e:
                raise TypeError(
                    f"extra[{key!r}] is not JSON-serializable: {e}") from e
        raise


def _tree_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str | os.PathLike, step: int, tree, *,
         extra: Optional[Dict[str, Any]] = None, keep_last: int = 3) -> Path:
    _ensure_json_extra(extra)  # fail fast, before any disk write
    extra = extra or {}
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _tree_paths(tree)
    manifest = {
        "format_version": FORMAT_VERSION,
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra,
        "time": time.time(),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i:05d}.npy", arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync directory contents before commit
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (final / "COMMIT").write_text("ok")

    # retention — committed steps beyond keep_last, plus any stale tmp dirs
    # abandoned by a writer that crashed before its rename (ours was either
    # renamed away above or never existed at this point)
    steps = sorted(p for p in root.glob("step_????????")
                   if (p / "COMMIT").exists())
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    for junk in root.glob("step_????????.tmp"):
        try:  # age-guarded: never race a concurrent save_async writer
            stale = time.time() - junk.stat().st_mtime > _STALE_TMP_S
        except OSError:
            continue
        if stale:
            shutil.rmtree(junk, ignore_errors=True)
    return final


def save_async(root, step, tree, *, extra=None, keep_last: int = 3):
    """Snapshot to host memory synchronously, write to disk in a thread."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    snapshot = jax.tree_util.tree_unflatten(treedef, host_leaves)

    t = threading.Thread(
        target=save, args=(root, step, snapshot),
        kwargs=dict(extra=extra, keep_last=keep_last), daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def latest_step(root) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted(p for p in root.glob("step_????????")
                   if (p / "COMMIT").exists())
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(root, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict[str, Any], int]:
    """Restore into the structure of `tree_like` (shapes must match).

    `shardings`: optional pytree of NamedSharding — leaves are device_put
    with them (elastic re-mesh happens here: the stored arrays are logical).
    Returns (tree, extra, step).
    """
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT (partial write?)")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs tree {len(leaves_like)}"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_like))
    for i, (like, sh) in enumerate(zip(leaves_like, sh_leaves)):
        arr = np.load(d / f"arr_{i:05d}.npy")
        want = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: shape {arr.shape} != expected {want}")
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest.get("extra", {}), step
