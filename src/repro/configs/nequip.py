"""nequip [gnn] — 5 layers, d_hidden=32, l_max=2, 8 RBF, cutoff 5,
E(3)-equivariant tensor products (arXiv:2101.03164; paper)."""
from ..models.gnn.nequip import NequIPConfig, nequip_init, nequip_loss
from .gnn_arch import GNNArch


def _build(meta):
    small = meta["d_feat"] <= 8
    cfg = NequIPConfig(
        d_in=meta["d_feat"],
        d_hidden=32 if not small else 8,
        n_layers=5 if not small else 2,
        n_rbf=8,
        cutoff=5.0,
        graph_level=meta["graph_level"],
    )

    def loss(params, gb):
        return nequip_loss(params, cfg, gb)

    return cfg, (lambda rng: nequip_init(rng, cfg)), loss


ARCH = GNNArch("nequip", _build, needs_positions=True)
