"""Arch registry: ``--arch <id>`` resolution for launcher/dryrun/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

_ARCH_MODULES = {
    # LM family
    "minitron-4b": ".minitron_4b",
    "gemma2-27b": ".gemma2_27b",
    "qwen3-1.7b": ".qwen3_1_7b",
    "qwen3-moe-30b-a3b": ".qwen3_moe_30b_a3b",
    "mixtral-8x7b": ".mixtral_8x7b",
    # GNN family
    "graphsage-reddit": ".graphsage_reddit",
    "schnet": ".schnet",
    "nequip": ".nequip",
    "graphcast": ".graphcast",
    # RecSys family
    "dlrm-rm2": ".dlrm_rm2",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_arch(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[name], package=__package__)
    return mod.ARCH


def all_cells():
    """Every (arch, shape) cell, with documented skips included."""
    cells = []
    for name in list_archs():
        arch = get_arch(name)
        for shape in arch.shapes():
            cells.append((name, shape, arch.skip_reason(shape)))
    return cells
