"""qwen3-1.7b [dense] — qk_norm, GQA (hf:Qwen/Qwen3-8B family; hf).

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936. Pure full attention
→ long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig
from .lm import LMArch

CONFIG = TransformerConfig(
    name="qwen3-1.7b",
    vocab=151_936,
    d_model=2048,
    n_layers=28,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    qk_norm=True,
    rope_base=1_000_000.0,
    attn_impl="chunked",
    remat=True,
)

REDUCED = TransformerConfig(
    name="qwen3-1.7b-reduced",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    qk_norm=True,
    attn_impl="dense",
    remat=False,
)

ARCH = LMArch("qwen3-1.7b", CONFIG, REDUCED, sub_quadratic=False)
