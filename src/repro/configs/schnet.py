"""schnet [gnn] — 3 interactions, d_hidden=64, 300 RBF, cutoff 10
(arXiv:1706.08566; paper)."""
from ..models.gnn.schnet import SchNetConfig, schnet_init, schnet_loss
from .gnn_arch import GNNArch


def _build(meta):
    small = meta["d_feat"] <= 8
    cfg = SchNetConfig(
        d_in=meta["d_feat"],
        d_hidden=64 if not small else 16,
        n_interactions=3,
        n_rbf=300 if not small else 20,
        cutoff=10.0,
        graph_level=meta["graph_level"],
        n_out=1 if meta["graph_level"] or meta["n_out"] == 1 else meta["n_out"],
    )

    def loss(params, gb):
        return schnet_loss(params, cfg, gb)

    return cfg, (lambda rng: schnet_init(rng, cfg)), loss


ARCH = GNNArch("schnet", _build, needs_positions=True)
