"""graphcast [gnn] — 16-layer encoder-processor-decoder mesh GNN,
d_hidden=512, mesh_refinement=6, sum aggregator, n_vars=227
(arXiv:2212.12794; unverified)."""
import jax.numpy as jnp

from ..models.gnn.common import node_regression_loss
from ..models.gnn.graphcast import (
    GraphCastConfig,
    graphcast_apply,
    graphcast_init,
    graphcast_loss,
)
from .gnn_arch import GNNArch


def _build(meta):
    small = meta["d_feat"] <= 8
    cfg = GraphCastConfig(
        d_in=meta["d_feat"],
        d_hidden=512 if not small else 16,
        n_layers=16 if not small else 2,
        n_vars=227 if not small else 4,
        mesh_refinement=6,
    )

    def loss(params, gb):
        pred = graphcast_apply(params, cfg, gb)
        # targets may be class ids / scalars / per-graph values for the
        # generic shapes — regress onto a broadcast target column (the cell
        # exercises the same kernels either way)
        tgt = gb.targets
        if tgt.ndim == 1 and tgt.shape[0] != pred.shape[0]:
            tgt = tgt[gb.graph_ids]          # per-graph → per-node
        if tgt.ndim == 1:
            tgt = jnp.broadcast_to(
                tgt.astype(jnp.float32)[:, None], pred.shape)
        return node_regression_loss(pred, tgt, gb.node_mask)

    return cfg, (lambda rng: graphcast_init(rng, cfg)), loss


ARCH = GNNArch("graphcast", _build, needs_positions=False)
