"""Architecture registry plumbing — every assigned arch is a `Arch` object
exposing uniform hooks the launcher, dry-run, smoke tests and roofline use:

  shapes()            → {shape_name: ShapeCell}
  skip_reason(shape)  → str | None        (documented skips, DESIGN.md §5)
  abstract_params()   → ShapeDtypeStruct pytree (full config, no allocation)
  init_reduced(rng)   → real params for the reduced smoke config
  input_specs(shape)  → ShapeDtypeStruct pytree of step inputs
  step_fn(shape)      → jittable (params, *inputs) step (train loss+grads or
                        serve forward), full config
  reduced_step_fn(shape) / reduced_inputs(shape) → smoke-test variants
  param_pspecs()      → PartitionSpec pytree for params
  input_pspecs(shape) → PartitionSpec pytree for step inputs
  model_flops(shape)  → analytic MODEL_FLOPS for §Roofline (6·N·D etc.)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.sharding import logical_spec

Params = Any


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                 # train | prefill | decode | serve | retrieval
    meta: Dict[str, Any]


def spec_tree_like(tree, fn: Callable[[Tuple, Any], P]):
    """Map (path, leaf) → PartitionSpec over an abstract pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(tuple(_key(p) for p in path), leaf), tree)


def _key(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def sds(shape, dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


class Arch:
    """Base class; family subclasses live in lm.py / gnn_arch.py / recsys.py."""

    name: str = "base"
    family: str = "base"

    # ---- to override -------------------------------------------------------
    def shapes(self) -> Dict[str, ShapeCell]:
        raise NotImplementedError

    def skip_reason(self, shape: str) -> Optional[str]:
        return None

    def abstract_params(self, shape: str = None):
        raise NotImplementedError

    def input_specs(self, shape: str):
        raise NotImplementedError

    def step_fn(self, shape: str) -> Callable:
        raise NotImplementedError

    def param_pspecs(self, shape: str = None):
        return spec_tree_like(self.abstract_params(shape),
                              lambda path, leaf: P())

    def input_pspecs(self, shape: str):
        return jax.tree_util.tree_map(lambda _: P(), self.input_specs(shape))

    def model_flops(self, shape: str) -> float:
        raise NotImplementedError

    # ---- smoke-test hooks ----------------------------------------------------
    def init_reduced(self, rng):
        raise NotImplementedError

    def reduced_inputs(self, shape: str, rng):
        raise NotImplementedError

    def reduced_step_fn(self, shape: str) -> Callable:
        raise NotImplementedError

    # ---- shared helpers ------------------------------------------------------
    def runnable_shapes(self):
        return {k: v for k, v in self.shapes().items()
                if self.skip_reason(k) is None}
