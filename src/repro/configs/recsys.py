"""RecSys-family Arch wrapper — DLRM shapes:

  train_batch     batch=65,536  (training: loss + grad + AdamW)
  serve_p99       batch=512     (online inference forward)
  serve_bulk      batch=262,144 (offline scoring forward)
  retrieval_cand  batch=1 × 1,000,000 candidates (batched-dot retrieval)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.dlrm import (
    DLRMConfig,
    dlrm_apply,
    dlrm_init,
    dlrm_loss,
    retrieval_score,
)
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from .base import Arch, ShapeCell, sds

BATCH_AXES = ("pod", "data")

RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", dict(batch=65_536)),
    "serve_p99": ShapeCell("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeCell("serve_bulk", "serve", dict(batch=262_144)),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}


def _dlrm_pspec(path, leaf) -> P:
    names = [str(p) for p in path]
    if "tables" in names:
        return P("model", None)  # row-sharded embedding tables
    return P(*([None] * len(leaf.shape)))


@dataclasses.dataclass
class RecsysArch(Arch):
    arch_name: str
    cfg: DLRMConfig
    reduced_cfg: DLRMConfig
    opt: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(lr=1e-3))
    family: str = "recsys"

    def __post_init__(self):
        self.name = self.arch_name

    def shapes(self) -> Dict[str, ShapeCell]:
        return dict(RECSYS_SHAPES)

    # ---- params ------------------------------------------------------------
    def abstract_params(self, shape: str = None):
        return jax.eval_shape(lambda: dlrm_init(jax.random.key(0), self.cfg))

    def init_reduced(self, rng):
        return dlrm_init(rng, self.reduced_cfg)

    def param_pspecs(self, shape: str = None):
        from .base import spec_tree_like

        return spec_tree_like(self.abstract_params(shape), _dlrm_pspec)

    def abstract_opt(self, shape: str = None):
        return jax.eval_shape(adamw_init, self.abstract_params(shape))

    def opt_pspecs(self, shape: str = None):
        from ..train.optimizer import AdamWState

        ps = self.param_pspecs(shape)
        return AdamWState(step=P(), mu=ps, nu=ps)

    # ---- inputs ------------------------------------------------------------
    def _b(self, shape: str, reduced: bool) -> int:
        if reduced:
            return {"train_batch": 32, "serve_p99": 8, "serve_bulk": 64,
                    "retrieval_cand": 1}[shape]
        return RECSYS_SHAPES[shape].meta["batch"]

    def input_specs(self, shape: str, *, reduced: bool = False):
        cfg = self.reduced_cfg if reduced else self.cfg
        B = self._b(shape, reduced)
        specs = {
            "dense": sds((B, cfg.n_dense), jnp.float32),
            "sparse_idx": sds((B, cfg.n_sparse, cfg.n_hot), jnp.int32),
        }
        kind = RECSYS_SHAPES[shape].kind
        if kind == "train":
            specs["labels"] = sds((B,), jnp.int32)
        if kind == "retrieval":
            C = 10_000 if reduced else RECSYS_SHAPES[shape].meta["n_candidates"]
            C = -(-C // 512) * 512  # pad to mesh-divisible (scores are ranked)
            specs["candidates"] = sds((C, cfg.embed_dim), jnp.float32)
        return specs

    def input_pspecs(self, shape: str):
        kind = RECSYS_SHAPES[shape].kind
        out = {
            "dense": P(BATCH_AXES, None),
            "sparse_idx": P(BATCH_AXES, None, None),
        }
        if kind == "train":
            out["labels"] = P(BATCH_AXES)
        if kind == "retrieval":
            out["dense"] = P(None, None)
            out["sparse_idx"] = P(None, None, None)
            out["candidates"] = P(("data", "model"), None)
        return out

    # ---- steps ---------------------------------------------------------------
    def step_fn(self, shape: str, *, reduced: bool = False) -> Callable:
        cfg = self.reduced_cfg if reduced else self.cfg
        kind = RECSYS_SHAPES[shape].kind
        opt_cfg = self.opt
        if kind == "train":
            def train_step(params, opt_state, dense, sparse_idx, labels):
                loss, grads = jax.value_and_grad(dlrm_loss)(
                    params, cfg, dense, sparse_idx, labels)
                params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
                return loss, params, opt_state
            return train_step
        if kind == "retrieval":
            def retr_step(params, dense, sparse_idx, candidates):
                return retrieval_score(params, cfg, dense, sparse_idx,
                                       candidates, top_k=100)
            return retr_step

        def serve_step(params, dense, sparse_idx):
            return jax.nn.sigmoid(dlrm_apply(params, cfg, dense, sparse_idx))
        return serve_step

    def reduced_step_fn(self, shape: str) -> Callable:
        return self.step_fn(shape, reduced=True)

    def reduced_inputs(self, shape: str, rng):
        cfg = self.reduced_cfg
        r = np.random.default_rng(0)
        specs = self.input_specs(shape, reduced=True)
        out = {}
        for k, v in specs.items():
            if v.dtype == jnp.int32:
                hi = cfg.table_rows if k == "sparse_idx" else 2
                out[k] = jnp.asarray(r.integers(0, hi, v.shape), jnp.int32)
            else:
                out[k] = jnp.asarray(r.normal(size=v.shape), jnp.float32)
        return out

    # ---- roofline --------------------------------------------------------------
    def model_flops(self, shape: str) -> float:
        cfg = self.cfg
        B = self._b(shape, False)
        kind = RECSYS_SHAPES[shape].kind
        dims_bot = (cfg.n_dense,) + cfg.bot_mlp
        dims_top = (cfg.top_in,) + cfg.top_mlp
        mlp = sum(2 * a * b for a, b in zip(dims_bot, dims_bot[1:]))
        mlp += sum(2 * a * b for a, b in zip(dims_top, dims_top[1:]))
        f = cfg.n_sparse + 1
        interact = 2 * f * f * cfg.embed_dim
        lookup = 2 * cfg.n_sparse * cfg.n_hot * cfg.embed_dim
        fwd = B * (mlp + interact + lookup)
        if kind == "train":
            return 3.0 * fwd
        if kind == "retrieval":
            C = RECSYS_SHAPES[shape].meta["n_candidates"]
            return fwd + 2.0 * B * C * cfg.embed_dim
        return float(fwd)


CONFIG = DLRMConfig(
    n_dense=13, n_sparse=26, embed_dim=64,
    bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1),
    table_rows=1_000_000, n_hot=1,
)

REDUCED = DLRMConfig(
    n_dense=13, n_sparse=26, embed_dim=16,
    bot_mlp=(32, 16), top_mlp=(64, 32, 1),
    table_rows=1000, n_hot=1,
)
