"""LM-family Arch wrapper: shapes, steps, shardings, roofline FLOPs.

The four assigned LM shapes (seq_len × global_batch):
  train_4k     4,096 × 256   — train_step (loss + grad + AdamW)
  prefill_32k  32,768 × 32   — serve prefill (forward, chunked attention)
  decode_32k   32,768 × 128  — serve_step: ONE new token, 32k KV cache
  long_500k    524,288 × 1   — long-context decode (skipped for pure
                               full-attention archs; see DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.transformer import (
    TransformerConfig,
    init_decode_cache,
    lm_loss,
    transformer_apply,
    transformer_decode,
    transformer_init,
)
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from .base import Arch, ShapeCell, sds, spec_tree_like

LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeCell("decode_32k", "decode", {"seq": 32768, "batch": 128}),
    "long_500k": ShapeCell("long_500k", "decode", {"seq": 524288, "batch": 1}),
}

_2D = ("wq", "wk", "wv", "wo", "wi", "wg")


def _wkv_mode() -> str:
    """Perf-experiment toggle (EXPERIMENTS.md §Perf, hypothesis H2).

    'col' (baseline): shard wk/wv output columns — splits head_dim when
        kv_heads < model axis, forcing an f32 scores all-reduce per layer.
    'replicated': keep wk/wv replicated (they are tiny under GQA) — no
        head_dim split, no scores all-reduce.
    """
    import os

    return os.environ.get("REPRO_WKV_MODE", "col")


def _lm_pspec(path, leaf) -> P:
    rank = len(leaf.shape)
    names = [p for p in path]
    if "embed" in names:
        base = ("model", None)        # (vocab, d_model)
    elif any(n in ("moe",) for n in names):
        nm = names[-1] if names[-1] != "kernel" else names[-2]
        if nm in ("wi", "wg", "wo"):
            base = ("model", None, None)   # (experts, ·, ·) — EP
        else:                               # router
            base = (None, None)
    else:
        nm = names[-2] if names[-1] == "kernel" else names[-1]
        if nm in ("wk", "wv") and _wkv_mode() == "replicated":
            base = (None, None)
        elif nm in ("wq", "wk", "wv", "wi", "wg"):
            base = (None, "model")
        elif nm == "wo":
            base = ("model", None)
        else:                               # norms etc.
            base = (None,) * min(rank, 1)
    pad = rank - len(base)
    return P(*((None,) * pad), *base)


@dataclasses.dataclass
class LMArch(Arch):
    arch_name: str
    cfg: TransformerConfig
    reduced_cfg: TransformerConfig
    sub_quadratic: bool = False  # window / local-global archs run long_500k
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    family: str = "lm"

    def __post_init__(self):
        self.name = self.arch_name

    # ---- cost-calibration hooks (see launch/dryrun.py) ----------------------
    # XLA cost analysis counts while-loop bodies once; the dry-run lowers an
    # unrolled 2-scan-step twin (U2) next to the scanned full model (S) and
    # solves body = U2 − S, corrected = S + (n_steps − 1)·body.
    def calibration_arch(self) -> "LMArch":
        cal = dataclasses.replace(
            self.cfg,
            n_layers=2 * self.cfg.layers_per_step,
            scan_layers=False)
        return dataclasses.replace(self, cfg=cal)

    @property
    def scan_steps(self) -> int:
        return self.cfg.n_scan_steps

    # ---- shapes -------------------------------------------------------------
    def shapes(self) -> Dict[str, ShapeCell]:
        return dict(LM_SHAPES)

    def skip_reason(self, shape: str) -> Optional[str]:
        if shape == "long_500k" and not self.sub_quadratic:
            return ("pure full-attention stack: no sub-quadratic path for "
                    "524k context (documented skip, DESIGN.md §5)")
        return None

    # ---- params ---------------------------------------------------------------
    def abstract_params(self, shape: str = None):
        return jax.eval_shape(
            lambda: transformer_init(jax.random.key(0), self.cfg))

    def init_reduced(self, rng):
        return transformer_init(rng, self.reduced_cfg)

    def param_pspecs(self, shape: str = None):
        return spec_tree_like(self.abstract_params(shape), _lm_pspec)

    def opt_pspecs(self, shape: str = None):
        from ..train.optimizer import AdamWState

        ps = self.param_pspecs(shape)
        return AdamWState(step=P(), mu=ps, nu=ps)

    def abstract_opt(self, shape: str = None):
        return jax.eval_shape(adamw_init, self.abstract_params(shape))

    # ---- inputs ---------------------------------------------------------------
    def _bs(self, shape: str, cfg: TransformerConfig):
        meta = LM_SHAPES[shape].meta
        if cfg is self.reduced_cfg:
            return {"train_4k": (2, 64), "prefill_32k": (2, 128),
                    "decode_32k": (4, 128), "long_500k": (1, 256)}[shape]
        return meta["batch"], meta["seq"]

    def input_specs(self, shape: str, *, reduced: bool = False):
        cfg = self.reduced_cfg if reduced else self.cfg
        B, S = self._bs(shape, cfg)
        kind = LM_SHAPES[shape].kind
        if kind == "train":
            return {"tokens": sds((B, S), jnp.int32),
                    "targets": sds((B, S), jnp.int32)}
        if kind == "prefill":
            return {"tokens": sds((B, S), jnp.int32)}
        cache = jax.eval_shape(lambda: init_decode_cache(cfg, B, S))
        return {"cache": cache,
                "tokens": sds((B, 1), jnp.int32),
                "positions": sds((B,), jnp.int32)}

    def input_pspecs(self, shape: str):
        kind = LM_SHAPES[shape].kind
        batch_axes = ("pod", "data")
        B, S = self._bs(shape, self.cfg)
        if kind in ("train", "prefill"):
            return jax.tree_util.tree_map(
                lambda _: P(batch_axes), self.input_specs(shape))
        # decode: cache (layers, B, L, KV, hd) — batch over data when it
        # divides, sequence over model (kv_seq); tokens/positions over batch
        seq_axes = ("model",) if B > 1 else ("data", "model")
        cache_spec = jax.tree_util.tree_map(
            lambda leaf: P(None, batch_axes if B > 1 else None,
                           seq_axes if len(seq_axes) > 1 else seq_axes[0]),
            self.input_specs(shape)["cache"])
        return {"cache": cache_spec,
                "tokens": P(batch_axes if B > 1 else None),
                "positions": P(batch_axes if B > 1 else None)}

    # ---- steps ----------------------------------------------------------------
    def _train_step(self, cfg: TransformerConfig):
        opt_cfg = self.opt

        def step(params, opt_state, tokens, targets):
            loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, targets)
            params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
            return loss, params, opt_state

        return step

    def _prefill_step(self, cfg: TransformerConfig):
        def step(params, tokens):
            logits, _ = transformer_apply(params, cfg, tokens)
            # serve prefill returns last-position logits only
            return logits[:, -1]

        return step

    def _decode_step(self, cfg: TransformerConfig):
        def step(params, cache, tokens, positions):
            return transformer_decode(params, cfg, cache, tokens, positions)

        return step

    def step_fn(self, shape: str, *, reduced: bool = False) -> Callable:
        cfg = self.reduced_cfg if reduced else self.cfg
        kind = LM_SHAPES[shape].kind
        if kind == "train":
            return self._train_step(cfg)
        if kind == "prefill":
            return self._prefill_step(cfg)
        return self._decode_step(cfg)

    def reduced_inputs(self, shape: str, rng):
        specs = self.input_specs(shape, reduced=True)
        cfg = self.reduced_cfg

        def make(leaf):
            if leaf.dtype == jnp.int32:
                return jnp.asarray(
                    np.random.default_rng(0).integers(0, cfg.vocab, leaf.shape),
                    jnp.int32)
            return jnp.zeros(leaf.shape, leaf.dtype)

        out = jax.tree_util.tree_map(make, specs)
        if "positions" in out:
            out["positions"] = jnp.zeros(out["positions"].shape, jnp.int32) + 3
        return out

    def reduced_step_fn(self, shape: str) -> Callable:
        return self.step_fn(shape, reduced=True)

    # ---- roofline ---------------------------------------------------------------
    def _attn_ctx(self, S: int, local: bool) -> float:
        cfg = self.cfg
        if cfg.local_global:
            w = cfg.window if local else None
        else:
            w = cfg.window
        return float(min(S, w)) if w is not None else float(S)

    def model_flops(self, shape: str) -> float:
        cfg = self.cfg
        B, S = self._bs(shape, cfg)
        kind = LM_SHAPES[shape].kind
        N = cfg.active_param_count()
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        # mean causal context per layer type
        if cfg.local_global:
            ctx = 0.5 * (min(S, cfg.window) + S)
        elif cfg.window is not None:
            ctx = min(S, cfg.window)
        else:
            ctx = S
        if kind == "train":
            return 6.0 * N * B * S + 6.0 * L * H * hd * ctx * B * S
        if kind == "prefill":
            return 2.0 * N * B * S + 2.0 * L * H * hd * ctx * B * S
        # decode: one token, full-cache attention reads
        return 2.0 * N * B + 4.0 * L * H * hd * ctx * B
