"""graphsage-reddit [gnn] — 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10 (arXiv:1706.02216; paper)."""
from ..models.gnn.graphsage import SAGEConfig, sage_init, sage_loss
from .gnn_arch import GNNArch


def _build(meta):
    cfg = SAGEConfig(
        d_in=meta["d_feat"],
        d_hidden=128 if meta["d_feat"] > 8 else 16,
        n_layers=2,
        n_classes=max(meta["n_out"], 1),
        aggregator="mean",
        graph_level=meta["graph_level"],
    )
    return cfg, (lambda rng: sage_init(rng, cfg)), (
        lambda params, gb: sage_loss(params, cfg, gb))


ARCH = GNNArch("graphsage-reddit", _build, needs_positions=False)
