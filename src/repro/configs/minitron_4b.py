"""minitron-4b [dense] — pruned Nemotron (arXiv:2407.14679; hf).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000. Pure full attention
→ long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig
from .lm import LMArch

CONFIG = TransformerConfig(
    name="minitron-4b",
    vocab=256_000,
    d_model=3072,
    n_layers=32,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    attn_impl="chunked",
    remat=True,
)

REDUCED = TransformerConfig(
    name="minitron-4b-reduced",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    attn_impl="dense",
    remat=False,
)

ARCH = LMArch("minitron-4b", CONFIG, REDUCED, sub_quadratic=False)
