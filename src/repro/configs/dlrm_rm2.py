"""dlrm-rm2 [recsys] — 13 dense / 26 sparse / embed 64 / dot interaction
(arXiv:1906.00091; paper)."""
from .recsys import CONFIG, REDUCED, RecsysArch

ARCH = RecsysArch("dlrm-rm2", CONFIG, REDUCED)
