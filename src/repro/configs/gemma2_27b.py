"""gemma2-27b [dense] — local+global alternating, logit softcap
(arXiv:2408.00118; hf).

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000. Local layers keep
a 4096-token rolling KV → long_500k runs (hybrid local/global).
"""
from ..models.transformer import TransformerConfig
from .lm import LMArch

CONFIG = TransformerConfig(
    name="gemma2-27b",
    vocab=256_000,
    d_model=4608,
    n_layers=46,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    local_global=True,
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_impl="chunked",
    remat=True,
)

REDUCED = TransformerConfig(
    name="gemma2-27b-reduced",
    vocab=512,
    d_model=64,
    n_layers=4,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    local_global=True,
    window=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_impl="dense",
    remat=False,
)

ARCH = LMArch("gemma2-27b", CONFIG, REDUCED, sub_quadratic=True)
