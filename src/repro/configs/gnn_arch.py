"""GNN-family Arch wrapper — four shapes shared by all four GNN archs:

  full_graph_sm   2,708 nodes / 10,556 edges / d_feat 1,433 (full-batch)
  minibatch_lg    232,965-node graph, sampled blocks: 1,024 seeds, fanout 15-10
  ogb_products    2,449,029 nodes / 61,859,140 edges / d_feat 100 (full-batch)
  molecule        30 nodes / 64 edges × batch 128 (batched small graphs)

Geometric models (SchNet/NequIP) consume positions; for non-molecular cells
the pipeline synthesizes positions (DESIGN.md §5) — the kernel regime is
what the cell exercises.  Every step is loss + grad + AdamW.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.gnn.common import GraphBatch
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from .base import Arch, ShapeCell, sds

# nodes/edges shard over (pod, data); a full-mesh variant was measured and
# REFUTED — random edge→node gathers across 256 shards tripled collective
# bytes (536 GiB/dev on ogb_products) for a 3× memory win; locality-aware
# partitioning (METIS-style) is the real lever and is future work
# (EXPERIMENTS.md §Perf bonus iteration).
NODE_AXES = ("pod", "data")

# (n_nodes, n_edges, d_feat, n_out, graph_level, n_graphs)
GNN_SHAPES: Dict[str, ShapeCell] = {
    "full_graph_sm": ShapeCell("full_graph_sm", "train", dict(
        n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7,
        graph_level=False, n_graphs=1)),
    "minibatch_lg": ShapeCell("minibatch_lg", "train", dict(
        # sampled block: 1024 seeds × fanout (15, 10)
        n_nodes=1024 * (1 + 15 + 150), n_edges=1024 * 15 + 1024 * 15 * 10,
        d_feat=602, n_out=41, graph_level=False, n_graphs=1,
        seeds=1024, fanout=(15, 10), graph_nodes=232_965,
        graph_edges=114_615_892)),
    "ogb_products": ShapeCell("ogb_products", "train", dict(
        n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_out=47,
        graph_level=False, n_graphs=1)),
    "molecule": ShapeCell("molecule", "train", dict(
        n_nodes=30 * 128, n_edges=64 * 2 * 128, d_feat=16, n_out=1,
        graph_level=True, n_graphs=128)),
}

_REDUCED_META = dict(n_nodes=64, n_edges=256, d_feat=8, n_out=4,
                     graph_level=False, n_graphs=1)


@dataclasses.dataclass
class GNNArch(Arch):
    """model_builder(meta, reduced) → (cfg, init_fn(rng), loss_fn(params, gb))."""

    arch_name: str
    model_builder: Callable
    needs_positions: bool = False
    opt: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(lr=1e-3))
    family: str = "gnn"

    def __post_init__(self):
        self.name = self.arch_name

    def shapes(self) -> Dict[str, ShapeCell]:
        return dict(GNN_SHAPES)

    def _meta(self, shape: str, reduced: bool):
        if reduced:
            meta = dict(_REDUCED_META)
            if shape == "molecule":
                meta.update(graph_level=True, n_graphs=4, n_out=1)
            return meta
        return GNN_SHAPES[shape].meta

    def _build(self, shape: str, reduced: bool = False):
        return self.model_builder(self._meta(shape, reduced))

    # ---- params ------------------------------------------------------------
    def abstract_params(self, shape: str = "full_graph_sm"):
        cfg, init_fn, loss_fn = self._build(shape)
        return jax.eval_shape(lambda: init_fn(jax.random.key(0)))

    def init_reduced(self, rng, shape: str = "full_graph_sm"):
        cfg, init_fn, loss_fn = self._build(shape, reduced=True)
        return init_fn(rng)

    def param_pspecs(self, shape: str = "full_graph_sm"):
        # GNN params are small — replicated; activations carry the sharding
        return jax.tree_util.tree_map(lambda _: P(),
                                      self.abstract_params(shape))

    def abstract_opt(self, shape: str = "full_graph_sm"):
        return jax.eval_shape(adamw_init, self.abstract_params(shape))

    def opt_pspecs(self, shape: str = "full_graph_sm"):
        from ..train.optimizer import AdamWState

        ps = self.param_pspecs(shape)
        return AdamWState(step=P(), mu=ps, nu=ps)

    # ---- inputs ------------------------------------------------------------
    @staticmethod
    def _pad(n: int, mult: int = 512) -> int:
        """Nodes/edges padded to mesh-divisible sizes (masked anyway)."""
        return -(-n // mult) * mult

    def _batch_specs(self, meta) -> GraphBatch:
        N, E = self._pad(meta["n_nodes"]), self._pad(meta["n_edges"])
        if meta["graph_level"]:
            tgt = sds((meta["n_graphs"],), jnp.float32)
        elif meta["n_out"] == 1:
            tgt = sds((N,), jnp.float32)
        else:
            tgt = sds((N,), jnp.int32)
        return GraphBatch(
            x=sds((N, meta["d_feat"]), jnp.float32),
            edge_src=sds((E,), jnp.int32),
            edge_dst=sds((E,), jnp.int32),
            edge_mask=sds((E,), jnp.bool_),
            node_mask=sds((N,), jnp.bool_),
            graph_ids=sds((N,), jnp.int32),
            n_graphs=meta["n_graphs"],
            targets=tgt,
            pos=sds((N, 3), jnp.float32) if self.needs_positions else None,
        )

    def input_specs(self, shape: str, *, reduced: bool = False):
        return {"batch": self._batch_specs(self._meta(shape, reduced))}

    def input_pspecs(self, shape: str):
        def leaf_spec(leaf):
            if leaf is None:
                return None
            return P(NODE_AXES, *([None] * (len(leaf.shape) - 1)))

        gb = self.input_specs(shape)["batch"]
        spec = jax.tree_util.tree_map(leaf_spec, gb)
        return {"batch": spec}

    # ---- steps ---------------------------------------------------------------
    def _mk_step(self, shape: str, reduced: bool):
        cfg, init_fn, loss_fn = self._build(shape, reduced)
        opt_cfg = self.opt

        def step(params, opt_state, batch: GraphBatch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
            return loss, params, opt_state

        return step

    def step_fn(self, shape: str, *, reduced: bool = False) -> Callable:
        return self._mk_step(shape, reduced)

    def reduced_step_fn(self, shape: str) -> Callable:
        return self._mk_step(shape, True)

    def reduced_inputs(self, shape: str, rng):
        meta = self._meta(shape, reduced=True)
        r = np.random.default_rng(0)
        N, E = meta["n_nodes"], meta["n_edges"]
        if meta["graph_level"]:
            tgt = jnp.asarray(r.normal(size=(meta["n_graphs"],)), jnp.float32)
        elif meta["n_out"] == 1:
            tgt = jnp.asarray(r.normal(size=(N,)), jnp.float32)
        else:
            tgt = jnp.asarray(r.integers(0, meta["n_out"], N), jnp.int32)
        gb = GraphBatch(
            x=jnp.asarray(r.normal(size=(N, meta["d_feat"])), jnp.float32),
            edge_src=jnp.asarray(r.integers(0, N, E), jnp.int32),
            edge_dst=jnp.asarray(r.integers(0, N, E), jnp.int32),
            edge_mask=jnp.ones((E,), bool),
            node_mask=jnp.ones((N,), bool),
            graph_ids=jnp.asarray(
                np.sort(r.integers(0, meta["n_graphs"], N)), jnp.int32),
            n_graphs=meta["n_graphs"],
            targets=tgt,
            pos=jnp.asarray(r.normal(size=(N, 3)), jnp.float32)
            if self.needs_positions else None,
        )
        return {"batch": gb}

    # ---- roofline --------------------------------------------------------------
    def model_flops(self, shape: str) -> float:
        cfg, _, _ = self._build(shape)
        meta = GNN_SHAPES[shape].meta
        N, E, F = meta["n_nodes"], meta["n_edges"], meta["d_feat"]
        H = getattr(cfg, "d_hidden", 128)
        L = (getattr(cfg, "n_layers", None)
             or getattr(cfg, "n_interactions", 2))
        # train ≈ 3 × fwd; fwd ≈ per-layer (edge MLP-ish on E + node mixing on N)
        per_layer = 2.0 * E * H * 2 + 2.0 * N * H * H
        return 3.0 * (2.0 * N * F * H + L * per_layer + 2.0 * N * H * meta["n_out"])
