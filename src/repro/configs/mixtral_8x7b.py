"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
(arXiv:2401.04088; hf).

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, window=4096.
SWA keeps a rolling KV → long_500k runs (sub-quadratic decode).
"""
from ..models.transformer import TransformerConfig
from .lm import LMArch

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    vocab=32_000,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    window=4096,
    attn_impl="chunked",
    remat=True,
)

REDUCED = TransformerConfig(
    name="mixtral-reduced",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    n_experts=4,
    top_k=2,
    moe_d_ff=32,
    window=16,
    attn_impl="dense",
    remat=False,
)

ARCH = LMArch("mixtral-8x7b", CONFIG, REDUCED, sub_quadratic=True)
