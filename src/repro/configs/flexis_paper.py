"""The paper's own workload as a dry-run config: distributed mIS mining.

Not one of the 10 assigned archs — this is FLEXIS itself on the production
mesh: a mico-scale data graph replicated per chip, match roots sharded over
the whole mesh, Luby conflict-resolution collectives across it.
"""
import dataclasses

# mining-cell geometry (mico-scale, paper Table 1)
N_VERTICES = 100_000
N_EDGES = 1_080_298
N_LABELS = 29
PATTERN_K = 4
MATCH_CAP = 8192
ROOT_BLOCK = 4096
CHUNK = 32
MAX_CHUNKS = 4
BISECT_ITERS = 8
