"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained d_ff=768
(hf:Qwen/Qwen3-30B-A3B; hf).

48L d_model=2048 32H (GQA kv=4) vocab=151936. Pure full attention →
long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig
from .lm import LMArch

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b",
    vocab=151_936,
    d_model=2048,
    n_layers=48,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    qk_norm=True,
    rope_base=1_000_000.0,
    attn_impl="chunked",
    remat=True,
)

REDUCED = TransformerConfig(
    name="qwen3-moe-reduced",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    n_experts=8,
    top_k=2,
    moe_d_ff=32,
    qk_norm=True,
    attn_impl="dense",
    remat=False,
)

ARCH = LMArch("qwen3-moe-30b-a3b", CONFIG, REDUCED, sub_quadratic=False)
