"""Drop-in mini implementation of the ``hypothesis`` API the test-suite uses.

The dev environment may not ship ``hypothesis`` (the container image is
intentionally frozen); rather than letting the whole suite die at collection,
``tests/conftest.py`` calls :func:`install` to register this module under the
``hypothesis`` name when the real library is absent.  CI installs the real
hypothesis from ``requirements-dev.txt``, so the fallback only runs where the
real thing cannot.

Scope (exactly the surface our tests consume):

  * ``given(*strategies)`` — draws each strategy per example and calls the
    test; deterministic per-test seed, failures re-raise with the example
    appended to the assertion context.
  * ``settings(max_examples=, deadline=, suppress_health_check=)`` decorator.
  * ``assume(cond)`` — aborts the current example without failing.
  * ``HealthCheck`` — attribute stand-ins.
  * ``strategies``: ``integers``, ``booleans``, ``lists``, ``sampled_from``,
    ``composite`` (with ``draw``).

No shrinking, no example database — a failing example prints its values so it
can be frozen into a regression test by hand.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types
import zlib

__all__ = [
    "given", "settings", "assume", "HealthCheck", "strategies", "install",
    "UnsatisfiedAssumption",
]

# Real hypothesis defaults to 100; the fallback trades coverage for wall time
# on the frozen container. Override with REPRO_HYPOTHESIS_MAX_EXAMPLES.
_DEFAULT_MAX_EXAMPLES = int(os.environ.get("REPRO_HYPOTHESIS_MAX_EXAMPLES", 25))
_MAX_ASSUME_RETRIES = 50


class UnsatisfiedAssumption(Exception):
    """Raised by assume() to discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Names accepted by settings(suppress_health_check=[...]); inert here."""

    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"
    large_base_example = "large_base_example"


class SearchStrategy:
    def do_draw(self, rnd: random.Random):
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)

    def filter(self, pred):
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self._base, self._fn = base, fn

    def do_draw(self, rnd):
        return self._fn(self._base.do_draw(rnd))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self._base, self._pred = base, pred

    def do_draw(self, rnd):
        for _ in range(_MAX_ASSUME_RETRIES):
            v = self._base.do_draw(rnd)
            if self._pred(v):
                return v
        raise UnsatisfiedAssumption()


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self._lo, self._hi = int(min_value), int(max_value)

    def do_draw(self, rnd):
        # bias toward the endpoints now and then, like hypothesis does
        r = rnd.random()
        if r < 0.05:
            return self._lo
        if r < 0.1:
            return self._hi
        return rnd.randint(self._lo, self._hi)


class _Booleans(SearchStrategy):
    def do_draw(self, rnd):
        return rnd.random() < 0.5


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self._elements = elements
        self._min = int(min_size)
        self._max = int(max_size if max_size is not None else min_size + 10)

    def do_draw(self, rnd):
        size = rnd.randint(self._min, self._max)
        return [self._elements.do_draw(rnd) for _ in range(size)]


class _SampledFrom(SearchStrategy):
    def __init__(self, options):
        self._options = list(options)

    def do_draw(self, rnd):
        return rnd.choice(self._options)


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self._fn, self._args, self._kwargs = fn, args, kwargs

    def do_draw(self, rnd):
        def draw(strategy):
            return strategy.do_draw(rnd)

        return self._fn(draw, *self._args, **self._kwargs)


def integers(min_value, max_value) -> SearchStrategy:
    return _Integers(min_value, max_value)


def booleans() -> SearchStrategy:
    return _Booleans()


def lists(elements, *, min_size=0, max_size=None) -> SearchStrategy:
    return _Lists(elements, min_size=min_size, max_size=max_size)


def sampled_from(options) -> SearchStrategy:
    return _SampledFrom(options)


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        return _Composite(fn, args, kwargs)

    return builder


def settings(max_examples=None, deadline=None, suppress_health_check=(),
             **_ignored):
    """Decorator; only max_examples is meaningful in the fallback."""

    def deco(test):
        if max_examples is not None:
            test._fallback_max_examples = int(max_examples)
        return test

    return deco


def given(*strategies_args, **strategies_kw):
    def deco(test):
        sig = inspect.signature(test)
        params = list(sig.parameters.values())
        # given fills the rightmost positional params (hypothesis semantics);
        # whatever remains on the left stays visible to pytest as fixtures.
        n_pos = len(strategies_args)
        kept = params[: len(params) - n_pos]
        kept = [p for p in kept if p.name not in strategies_kw]
        # drawn values are passed by name so pytest-provided params
        # (parametrize/fixtures, delivered as kwargs) never collide
        drawn_names = [p.name for p in params[len(params) - n_pos:]]

        @functools.wraps(test)
        def wrapper(*fixture_args, **fixture_kw):
            max_examples = getattr(
                wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(
                f"{test.__module__}.{test.__qualname__}".encode())
            rnd = random.Random(seed)
            ran = 0
            for example_idx in range(max_examples):
                for _attempt in range(_MAX_ASSUME_RETRIES):
                    try:
                        drawn = [s.do_draw(rnd) for s in strategies_args]
                        drawn_kw = {name: s.do_draw(rnd)
                                    for name, s in strategies_kw.items()}
                    except UnsatisfiedAssumption:
                        continue
                    try:
                        test(*fixture_args,
                             **{**fixture_kw, **drawn_kw,
                                **dict(zip(drawn_names, drawn))})
                        ran += 1
                        break
                    except UnsatisfiedAssumption:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (#{example_idx}, seed "
                            f"{seed}): args={drawn!r} kwargs={drawn_kw!r}"
                        ) from e
            if ran == 0:
                raise UnsatisfiedAssumption(
                    f"{test.__qualname__}: no example satisfied assume() in "
                    f"{max_examples} tries")

        # hide the given-supplied params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.__dict__.pop("__wrapped__", None)
        if hasattr(test, "_fallback_max_examples"):
            wrapper._fallback_max_examples = test._fallback_max_examples
        return wrapper

    return deco


def install() -> types.ModuleType:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    this = sys.modules[__name__]
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.UnsatisfiedAssumption = UnsatisfiedAssumption
    hyp.__fallback__ = this

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "lists", "sampled_from", "composite",
                 "SearchStrategy"):
        setattr(st_mod, name, getattr(this, name))
    hyp.strategies = st_mod

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    return hyp
