"""Public wrapper: drop-in replacement for `mis_greedy_update`."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import mis_bitmap_select


def mis_greedy_update_kernel(bitmap, count, emb, n_valid, tau, k: int,
                             *, interpret: bool = True):
    """Same signature/result as repro.core.mis.mis_greedy_update.

    interpret=True by default (this container is CPU); pass False on TPU.
    """
    cap = emb.shape[0]
    block = 256
    while cap % block:
        block //= 2
    return mis_bitmap_select(bitmap, count, emb, jnp.int32(n_valid),
                             jnp.int32(tau), k=k, block_rows=max(block, 1),
                             interpret=interpret)
