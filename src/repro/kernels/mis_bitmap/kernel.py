"""Greedy mIS selection — Pallas TPU kernel with a VMEM-resident bitmap.

The paper's metric step shares one used-vertex bitmap across all VF3 states;
here the bitmap (packed uint32, shaped (Nw, 1) so dynamic indexing rides the
sublane axis) stays resident in VMEM scratch across the whole scan — zero
HBM traffic per candidate — while embedding rows stream through in blocks.
The scan is inherently sequential (that IS greedy mIS); the win over the
XLA `lax.scan` lowering is locality: no per-row gather/scatter round-trips.

Grid: (cap / block_rows,). Scratch: bitmap (Nw, 1) VMEM + count (1, 1) SMEM,
persisting across sequential grid steps (TPU grids execute in order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mis_kernel(nvalid_ref, tau_ref, emb_ref, bitmap_in_ref,
                count_in_ref, bitmap_out_ref, count_out_ref,
                bitmap_scr, count_scr, *, block_rows: int, k: int,
                n_blocks: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        bitmap_scr[...] = bitmap_in_ref[...]
        count_scr[0, 0] = count_in_ref[0, 0]

    n_valid = nvalid_ref[0, 0]
    tau = tau_ref[0, 0]

    def row_body(r, _):
        row_global = g * block_rows + r
        valid = row_global < n_valid
        # gather words/bits for this row's k vertices (k is small: unrolled)
        free = valid & (count_scr[0, 0] < tau)
        words = []
        bits = []
        for j in range(k):
            v = jnp.maximum(emb_ref[r, j], 0)
            w = (v >> 5).astype(jnp.int32)
            b = (jnp.uint32(1) << (v & 31).astype(jnp.uint32))
            words.append(w)
            bits.append(b)
            free &= (bitmap_scr[w, 0] & b) == 0
        take = free
        # sequential within-row updates keep shared-word vertices correct
        for j in range(k):
            cur = bitmap_scr[words[j], 0]
            bitmap_scr[words[j], 0] = jnp.where(take, cur | bits[j], cur)
        count_scr[0, 0] = count_scr[0, 0] + take.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, block_rows, row_body, 0)

    @pl.when(g == n_blocks - 1)
    def _finish():
        bitmap_out_ref[...] = bitmap_scr[...]
        count_out_ref[0, 0] = count_scr[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_rows", "interpret"))
def mis_bitmap_select(bitmap, count, emb, n_valid, tau, *, k: int,
                      block_rows: int = 256, interpret: bool = False):
    """bitmap: (Nw,) uint32; emb: (cap, K≥k) int32; returns (bitmap, count).

    Equivalent to `repro.core.mis.mis_greedy_update` (property-tested).
    """
    cap = emb.shape[0]
    block_rows = min(block_rows, cap)
    assert cap % block_rows == 0
    n_blocks = cap // block_rows
    Nw = bitmap.shape[0]

    kernel = functools.partial(_mis_kernel, block_rows=block_rows, k=k,
                               n_blocks=n_blocks)
    bm2, cnt2 = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # n_valid (1,1)
            pl.BlockSpec(memory_space=pltpu.SMEM),            # tau (1,1)
            pl.BlockSpec((block_rows, emb.shape[1]), lambda g: (g, 0)),
            pl.BlockSpec((Nw, 1), lambda g: (0, 0)),          # bitmap in
            pl.BlockSpec(memory_space=pltpu.SMEM),            # count (1,1)
        ],
        out_specs=[
            pl.BlockSpec((Nw, 1), lambda g: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Nw, 1), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Nw, 1), jnp.uint32),
            pltpu.SMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(n_valid, jnp.int32).reshape(1, 1),
        jnp.asarray(tau, jnp.int32).reshape(1, 1),
        emb,
        bitmap.reshape(Nw, 1),
        jnp.asarray(count, jnp.int32).reshape(1, 1),
    )
    return bm2.reshape(Nw), cnt2[0, 0]
