"""Oracle for the mIS bitmap kernel = the production jnp implementation."""
from repro.core.mis import bitmap_init, mis_greedy_update


def mis_bitmap_ref(bitmap, count, emb, n_valid, tau, k):
    """Greedy lexicographic maximal-independent-set selection (jnp scan)."""
    return mis_greedy_update(bitmap, count, emb, n_valid, tau, k)
