"""Fused frontier expansion — one Pallas program per match level.

The XLA expansion pipeline (``core/matcher._expand_level``) lowers to a
chain of separate HLOs per chunk — adjacency gather, cheap-filter mask,
edge-existence bisection, cumsum compaction — and the (cap × chunk)
candidate grid plus both frontier tables round-trip through HBM between
every stage.  This kernel runs the *whole level* as a single Pallas
program: the input frontier, the data-graph CSR arrays, and the output
frontier are pinned in VMEM for the duration of the call, the chunk loop
is a ``fori_loop`` inside the kernel, and compaction appends survivors
into a VMEM-resident output tile — zero HBM traffic between stages.

Semantics are the *single-phase* pipeline (``MatchConfig.two_phase=False``)
and are bit-identical to it, including the candidate ordering that the
greedy-mIS metric depends on: survivors are appended in (chunk, row,
position) order, exactly the order the XLA cumsum compaction produces.

Batched plane: the kernel is ``vmap``-able — JAX's Pallas batching rule
prepends the mapped pattern axis as a leading *grid* dimension, so a whole
same-k candidate level (``core/batched.py``) runs as one kernel launch
whose grid carries the pattern axis, instead of re-entering the kernel per
pattern.  The kernel body is grid-index-free, which keeps that transform
sound.

Lowering note: the body uses vector gathers (CSR rows, labels) and a
scatter-compaction; Mosaic support for these lowerings varies by TPU
generation/jaxlib.  Correctness is guaranteed in interpret mode
(``interpret=True``, the default on this CPU container) and
property-tested against the XLA pipeline; ``docs/kernels.md`` documents
the fallback rule.

VMEM budget: the graph CSR arrays plus two (cap, k) frontier tiles plus
the transient (cap·chunk, k) candidate rows must fit in VMEM (~16 MB/core)
— `frontier_expand_vmem_bytes` estimates the footprint, and
`frontier_expand` enforces it at trace time when lowering for hardware
(interpret=False), so oversized geometries fail with a right-sizing hint
instead of a Mosaic compile error.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.matcher import edge_exists


# conservative per-core VMEM budget the hardware guard checks against
_VMEM_BUDGET_BYTES = 16 * 2**20


def frontier_expand_vmem_bytes(n: int, n_index_entries: int, cap: int,
                               chunk: int, k: int) -> int:
    """Rough VMEM-resident footprint of one fused-level call, in bytes.

    n_index_entries = len(out_indices) + len(in_indices) (2·E for a fully
    mirrored graph; 2 for the edgeless sentinels).  Counts the graph arrays
    (labels + two indptr + the concatenated ``indices_cat`` operand plus
    the out-prefix slice the bisection reads, ≈1.5× the stored index
    entries), the in/out frontier tiles, and the (cap·chunk) candidate grid
    with its (cap·chunk, k) expanded rows.  `frontier_expand` refuses
    geometries past ~16 MiB when lowering for hardware (interpret=False).
    """
    graph = (n + 2 * (n + 1) + 3 * max(n_index_entries, 2) // 2) * 4
    frontier = 2 * cap * k * 4
    grid = cap * chunk * (k + 4) * 4
    return graph + frontier + grid


def _frontier_kernel(emb_ref, count_ref, labels_ref, out_indptr_ref,
                     in_indptr_ref, indices_cat_ref,
                     anchor_pos_ref, use_out_ref, cand_label_ref,
                     min_out_ref, min_in_ref, check_out_ref, check_in_ref,
                     out_emb_ref, out_count_ref, found_ref, ovf_ref,
                     *, level: int, k: int, cap: int, chunk: int,
                     max_chunks: int, bisect_iters: int, n: int, n_out: int):
    i = level  # static: the pattern-order column being filled
    C = chunk

    # ---- load VMEM-resident operands once --------------------------------
    emb = emb_ref[...]                       # (cap, k) int32
    count = count_ref[0, 0]
    labels = labels_ref[...][:, 0]           # (n,)
    out_indptr = out_indptr_ref[...][:, 0]   # (n+1,)
    in_indptr = in_indptr_ref[...][:, 0]
    # one concatenated [out_indices ‖ in_indices] operand; the bisection's
    # out-CSR view is its static-length prefix (no duplicate VMEM copies)
    indices_cat = indices_cat_ref[...][:, 0]
    out_indices = indices_cat[:n_out]

    anchor_pos = anchor_pos_ref[0, 0]
    use_out = use_out_ref[0, 0] != 0
    cand_label = cand_label_ref[0, 0]
    min_out = min_out_ref[0, 0]
    min_in = min_in_ref[0, 0]

    # ---- per-row anchor state (computed once, reused by every chunk) -----
    # anchor_pos < i always (anchors live in the ordered prefix), so an
    # unrolled select over the prefix columns replaces a dynamic gather.
    anchors = emb[:, 0]
    for j in range(1, i):
        anchors = jnp.where(anchor_pos == j, emb[:, j], anchors)
    anchors_safe = jnp.clip(anchors, 0, n - 1)
    out_start = out_indptr[anchors_safe]
    in_start = in_indptr[anchors_safe]
    start = jnp.where(use_out, out_start, in_start + n_out)
    deg = jnp.where(
        use_out,
        out_indptr[anchors_safe + 1] - out_start,
        in_indptr[anchors_safe + 1] - in_start,
    )
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (cap, 1), 0)[:, 0]
    row_valid = row_ids < count

    def cheap_mask(cand, cand_safe, in_deg_range):
        m = row_valid[:, None] & in_deg_range
        m &= labels[cand_safe] == cand_label
        m &= (out_indptr[cand_safe + 1] - out_indptr[cand_safe]) >= min_out
        m &= (in_indptr[cand_safe + 1] - in_indptr[cand_safe]) >= min_in
        for j in range(i):  # injectivity against the prefix (static unroll)
            m &= cand != emb[:, j][:, None]
        return m

    def edge_checks(cand_safe):
        ok = jnp.ones(cand_safe.shape, bool)
        for j in range(i):
            prev_safe = jnp.clip(emb[:, j], 0, n - 1)[:, None]   # (cap, 1)
            ok_out = edge_exists(out_indptr, out_indices, cand_safe,
                                 prev_safe, bisect_iters)
            ok_in = edge_exists(out_indptr, out_indices, prev_safe,
                                cand_safe, bisect_iters)
            ok &= jnp.where(check_out_ref[0, j] != 0, ok_out, True)
            ok &= jnp.where(check_in_ref[0, j] != 0, ok_in, True)
        return ok

    def chunk_body(c, carry):
        out_emb, out_count, found = carry
        off = c * C + jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
        idx = start[:, None] + off                              # (cap, C)
        in_deg_range = off < deg[:, None]
        cand = indices_cat[jnp.clip(idx, 0, indices_cat.shape[0] - 1)]
        cand_safe = jnp.clip(cand, 0, n - 1)
        mask = cheap_mask(cand, cand_safe, in_deg_range)
        mask &= edge_checks(cand_safe)
        flat = mask.reshape(-1)                                 # (cap·C,)
        n_new = flat.sum().astype(jnp.int32)
        pos = jnp.cumsum(flat).astype(jnp.int32) - 1 + out_count
        dest = jnp.where(flat & (pos < cap), pos, cap)          # cap ⇒ drop
        rows = jnp.broadcast_to(emb[:, None, :], (cap, C, k)).reshape(-1, k)
        rows = rows.at[:, i].set(cand.reshape(-1))
        out_emb = out_emb.at[dest].set(rows, mode="drop")
        return out_emb, jnp.minimum(out_count + n_new, cap), found + n_new

    out_emb0 = jnp.full((cap, k), -1, jnp.int32)
    out_emb, out_count, found = jax.lax.fori_loop(
        0, max_chunks, chunk_body, (out_emb0, jnp.int32(0), jnp.int32(0)))

    out_emb_ref[...] = out_emb
    out_count_ref[0, 0] = out_count
    found_ref[0, 0] = found
    ovf_ref[0, 0] = (found > cap).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("level", "k", "cap", "chunk", "max_chunks",
                     "bisect_iters", "n", "interpret"))
def frontier_expand(labels, out_indptr, out_indices, in_indptr, in_indices,
                    emb, count, anchor_pos, use_out, cand_label, min_out,
                    min_in, check_out_row, check_in_row, *, level: int,
                    k: int, cap: int, chunk: int, max_chunks: int,
                    bisect_iters: int, n: int, interpret: bool = False):
    """Run one fused expansion level.

    Args (all jnp, int32 unless noted):
      labels (n,); out_indptr/in_indptr (n+1,); out_indices/in_indices (E,)
      — edgeless graphs pass the 1-element sentinel arrays that
      ``DeviceGraph.from_host`` builds.
      emb (cap, k) frontier, columns ≥ `level` are -1; count () valid rows.
      anchor_pos/use_out/cand_label/min_out/min_in: () plan scalars for this
      level (use_out bool-ish).
      check_out_row/check_in_row: (k,) bool-ish — plan.check_out[level].
    Returns (out_emb (cap, k) int32, out_count (), found (), overflowed ()
    bool) — bit-identical to the single-phase XLA pipeline.
    """
    n_out = out_indices.shape[0]
    if not interpret:
        need = frontier_expand_vmem_bytes(
            n, n_out + in_indices.shape[0], cap, chunk, k)
        if need > _VMEM_BUDGET_BYTES:
            raise ValueError(
                f"frontier_expand geometry needs ~{need / 2**20:.1f} MiB of "
                f"VMEM (> {_VMEM_BUDGET_BYTES / 2**20:.0f} MiB); shrink "
                f"cap/chunk (cap={cap}, chunk={chunk}, k={k}, n={n}) or use "
                f'expansion="xla"')

    kern = functools.partial(
        _frontier_kernel, level=level, k=k, cap=cap, chunk=chunk,
        max_chunks=max_chunks, bisect_iters=bisect_iters, n=n, n_out=n_out)

    def smem_i32(x):
        return jnp.asarray(x, jnp.int32).reshape(1, 1)

    out_emb, out_count, found, ovf = pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),   # emb (cap, k)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # count (1, 1)
            pl.BlockSpec(memory_space=pltpu.VMEM),   # labels (n, 1)
            pl.BlockSpec(memory_space=pltpu.VMEM),   # out_indptr (n+1, 1)
            pl.BlockSpec(memory_space=pltpu.VMEM),   # in_indptr (n+1, 1)
            pl.BlockSpec(memory_space=pltpu.VMEM),   # indices_cat (2E, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # anchor_pos (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # use_out (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # cand_label (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # min_out (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # min_in (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # check_out_row (1, k)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # check_in_row (1, k)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap, k), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        emb,
        smem_i32(count),
        labels[:, None],
        out_indptr[:, None],
        in_indptr[:, None],
        jnp.concatenate([out_indices, in_indices])[:, None],
        smem_i32(anchor_pos),
        smem_i32(use_out),
        smem_i32(cand_label),
        smem_i32(min_out),
        smem_i32(min_in),
        jnp.asarray(check_out_row, jnp.int32).reshape(1, k),
        jnp.asarray(check_in_row, jnp.int32).reshape(1, k),
    )
    return out_emb, out_count[0, 0], found[0, 0], ovf[0, 0] != 0
