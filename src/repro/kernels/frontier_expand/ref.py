"""Oracle for the fused frontier-expansion kernel = the XLA pipeline.

The reference is the production single-phase expansion in
``core/matcher._expand_level`` (``two_phase=False``, ``expansion="xla"``):
gather → cheap mask → edge bisection → cumsum compaction, one XLA op
chain per chunk.  The kernel is bit-identical to this path, including the
(chunk, row, position) survivor ordering the greedy-mIS metric depends on.
"""
from __future__ import annotations

import dataclasses

from repro.core.matcher import MatchConfig, _expand_level


def frontier_expand_ref(g, plan, emb, count, level: int, cfg: MatchConfig):
    """Single-phase XLA expansion of one level; same returns as the kernel:
    (out_emb (cap, k) int32, out_count (), found (), overflowed () bool).

    The XLA pipeline defers the found > cap overflow check to
    ``match_block``; the kernel flags it per level.  The ref normalizes to
    the kernel's contract so the two are comparable level-by-level —
    ``match_block`` results are identical either way (it ORs the same
    check back in).
    """
    cfg = dataclasses.replace(cfg, expansion="xla", two_phase=False)
    out_emb, out_count, found, ovf = _expand_level(g, plan, emb, count,
                                                   level, cfg)
    return out_emb, out_count, found, ovf | (found > cfg.cap)
