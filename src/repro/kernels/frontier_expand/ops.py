"""Public wrapper: drop-in replacement for the XLA expansion pipeline.

``core/matcher._expand_level`` dispatches here when
``MatchConfig.expansion == "pallas"``; the whole expansion level then runs
as one fused Pallas program (see ``kernel.py``) instead of the per-chunk
XLA op chain.  Under ``vmap`` (the batched data plane) the pattern axis
becomes a leading kernel-grid dimension — one launch per level, not one
per pattern.
"""
from __future__ import annotations

from .kernel import frontier_expand


def frontier_expand_level(g, plan, emb, count, level: int, cfg, *,
                          interpret=None):
    """Same signature/result as the single-phase ``_expand_level`` pipeline.

    g: DeviceGraph; plan: PatternPlan; emb (cap, k) int32; count () int32.
    interpret defaults to ``cfg.pallas_interpret`` (True on this CPU
    container; set False on TPU for the fused lowering).
    Returns (out_emb (cap, k) int32, out_count (), found (), ovf () bool).
    """
    if interpret is None:
        interpret = cfg.pallas_interpret
    i = level
    return frontier_expand(
        g.labels, g.out_indptr, g.out_indices, g.in_indptr, g.in_indices,
        emb, count,
        plan.anchor_pos[i], plan.anchor_out[i], plan.cand_label[i],
        plan.min_out[i], plan.min_in[i],
        plan.check_out[i], plan.check_in[i],
        level=i, k=plan.k, cap=cfg.cap, chunk=cfg.chunk,
        max_chunks=cfg.max_chunks, bisect_iters=cfg.bisect_iters, n=g.n,
        interpret=interpret,
    )
