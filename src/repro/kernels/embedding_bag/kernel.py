"""EmbeddingBag — Pallas TPU kernel (the RecSys lookup hot path).

The table stays in HBM (`memory_space=ANY`); bag indices arrive via scalar
prefetch (SMEM) so each grid step can DMA exactly the `hots` rows it needs
into a VMEM scratch row and reduce them there.  One grid step = one block of
bags; per bag the kernel issues `hots` dynamic-slice copies (HBM→VMEM) and
accumulates — the classic FBGEMM-style gather-reduce reshaped for the TPU
DMA engine (contiguous (1, D) row copies, D lane-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_ref, out_ref, row_scr, sem, *,
                bags_per_block: int, hots: int, mean: bool):
    g = pl.program_id(0)

    def bag_body(b, _):
        acc = jnp.zeros_like(row_scr)
        cnt = jnp.int32(0)

        def hot_body(h, carry):
            acc, cnt = carry
            raw = idx_ref[(g * bags_per_block + b) * hots + h]
            valid = raw >= 0
            row = jnp.maximum(raw, 0)
            copy = pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1)], row_scr.at[:], sem)
            copy.start()
            copy.wait()
            acc = acc + jnp.where(valid, row_scr[...], 0.0)
            return acc, cnt + valid.astype(jnp.int32)

        acc, cnt = jax.lax.fori_loop(0, hots, hot_body, (acc, cnt))
        if mean:
            acc = acc / jnp.maximum(cnt, 1).astype(acc.dtype)
        out_ref[b] = acc[0].astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bags_per_block, bag_body, 0)


@functools.partial(jax.jit, static_argnames=("combiner", "bags_per_block",
                                             "interpret"))
def embedding_bag_pallas(table: jnp.ndarray, idx: jnp.ndarray, *,
                         combiner: str = "sum", bags_per_block: int = 64,
                         interpret: bool = False) -> jnp.ndarray:
    """table: (R, D) f32; idx: (B, H) int32 (pad = -1) → (B, D)."""
    R, D = table.shape
    B, H = idx.shape
    bags_per_block = min(bags_per_block, B)
    assert B % bags_per_block == 0
    n_blocks = B // bags_per_block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],     # table in HBM
        out_specs=pl.BlockSpec((bags_per_block, D), lambda g, idx: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), table.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_bag_kernel, bags_per_block=bags_per_block,
                               hots=H, mean=(combiner == "mean"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), table)
