"""Public wrapper for the embedding-bag kernel."""
from __future__ import annotations

from .kernel import embedding_bag_pallas


def embedding_bag(table, idx, *, combiner: str = "sum",
                  bags_per_block: int = 64, interpret: bool = True):
    """interpret=True default for this CPU container; False on TPU."""
    return embedding_bag_pallas(table, idx, combiner=combiner,
                                bags_per_block=bags_per_block,
                                interpret=interpret)
