"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray,
                      *, combiner: str = "sum") -> jnp.ndarray:
    """table: (R, D); idx: (B, H) int32, pad = -1 → (B, D)."""
    mask = idx >= 0
    rows = jnp.take(table, jnp.maximum(idx, 0), axis=0)
    rows = jnp.where(mask[..., None], rows, 0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
    return out
