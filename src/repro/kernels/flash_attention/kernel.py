"""Blocked causal flash attention — Pallas TPU kernel.

Grid (B·H, S/bq, S/bk); the innermost k-axis is sequential on TPU, so the
online-softmax state (m, l, acc) lives in VMEM scratch and persists across
k-steps.  GQA is handled by mapping query head h → kv head h // G inside the
BlockSpec index maps (no KV broadcast through HBM).  Causal/window-dead
blocks are skipped with @pl.when — the block never leaves HBM.

VMEM working set per step = q(bq·hd) + k(bk·hd) + v(bk·hd) + acc(bq·hd)
(+ scores bq·bk), all fp32 ≤ ~2 MB at the default 256/512 tiling — well
inside the 16 MB/core budget, with MXU-aligned (multiple-of-128) tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq: int, bk: int, nk: int, scale: float,
                 causal: bool, window: Optional[int],
                 softcap: Optional[float]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block-level liveness: any (query, key) pair unmasked?
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = live & (q_start - (k_start + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=None, softcap=None,
                         bq=256, bk=512, interpret=False):
    """q: (BH, S, hd); k/v: (BKV, S, hd); head i reads kv row i // G."""
    BH, S, hd = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, "S must divide block sizes"
    nq, nk = S // bq, S // bk
    grid = (BH, nq, nk)

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, scale=1.0 / np.sqrt(hd),
        causal=causal, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, qi, ki: (i // G, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, qi, ki: (i // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
