"""jit'd public wrapper for the flash-attention kernel (GQA layout glue)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, bq: int = 256,
                    bk: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) → (B, S, H, hd).

    Forward-only (serving / fwd benches); the differentiable train path uses
    the chunked-jnp oracle in `repro.models.attention`.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               softcap=softcap, bq=bq, bk=bk,
                               interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
