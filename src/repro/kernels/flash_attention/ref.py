"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, window,
softcap). Shapes: q (B, S, H, hd); k/v (B, S, KV, hd) with H % KV == 0."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    s = s / np.sqrt(hd)
    if softcap is not None:
        s = softcap_ * jnp.tanh(s / softcap_) if (softcap_ := softcap) else s
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd).astype(q.dtype)
