"""Public wrapper for the gather-aggregate kernel + CSR→padded helper."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import gather_aggregate_pallas


def pad_adjacency(indptr: np.ndarray, indices: np.ndarray, d_max: int
                  ) -> np.ndarray:
    """CSR → (N, d_max) padded neighbor table (pad = -1, degree-capped)."""
    n = indptr.shape[0] - 1
    out = np.full((n, d_max), -1, np.int32)
    for v in range(n):
        row = indices[indptr[v]:indptr[v + 1]][:d_max]
        out[v, : row.shape[0]] = row
    return out


def gather_aggregate(features, nbrs, *, mean: bool = False,
                     block_nodes: int = 256, interpret: bool = True):
    """interpret=True default for this CPU container; False on TPU."""
    return gather_aggregate_pallas(features, nbrs, mean=mean,
                                   block_nodes=block_nodes,
                                   interpret=interpret)
