"""Padded-neighbor gather-aggregate — the GNN SpMM hot path as a Pallas
TPU kernel.

Complementary regime to the embedding-bag kernel: here the *feature matrix
block* is VMEM-resident and neighbor rows are read with dynamic sublane
indexing (no per-row DMA).  The neighbor table streams through VMEM in node
blocks; output is the masked neighbor-sum (mean optional) — i.e.
``Ã·X`` for GCN/GraphSAGE aggregation over a degree-capped adjacency.

Feature blocks must fit VMEM: (block_src, F) f32 ≤ ~4 MB (e.g. 4096×128).
For features larger than VMEM, fall back to `repro.models.gnn.common`
segment_sum (HBM path) — the launcher picks per shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(feat_ref, nbr_ref, out_ref, *, block_nodes: int,
                dmax: int, mean: bool):
    def node_body(i, _):
        acc = jnp.zeros((1, feat_ref.shape[1]), jnp.float32)
        cnt = jnp.int32(0)

        def nbr_body(j, carry):
            acc, cnt = carry
            raw = nbr_ref[i, j]
            valid = raw >= 0
            row = jnp.maximum(raw, 0)
            feat = feat_ref[pl.ds(row, 1), :].astype(jnp.float32)
            acc = acc + jnp.where(valid, feat, 0.0)
            return acc, cnt + valid.astype(jnp.int32)

        acc, cnt = jax.lax.fori_loop(0, dmax, nbr_body, (acc, cnt))
        if mean:
            acc = acc / jnp.maximum(cnt, 1).astype(jnp.float32)
        out_ref[pl.ds(i, 1), :] = acc.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_nodes, node_body, 0)


@functools.partial(jax.jit, static_argnames=("mean", "block_nodes",
                                             "interpret"))
def gather_aggregate_pallas(features: jnp.ndarray, nbrs: jnp.ndarray, *,
                            mean: bool = False, block_nodes: int = 256,
                            interpret: bool = False) -> jnp.ndarray:
    """features: (N, F); nbrs: (N, Dmax) int32 (pad = -1) → (N, F)."""
    N, F = features.shape
    Nn, Dmax = nbrs.shape
    assert Nn == N
    block_nodes = min(block_nodes, N)
    assert N % block_nodes == 0
    grid = (N // block_nodes,)
    kernel = functools.partial(_agg_kernel, block_nodes=block_nodes,
                               dmax=Dmax, mean=mean)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, F), lambda g: (0, 0)),              # full features
            pl.BlockSpec((block_nodes, Dmax), lambda g: (g, 0)),  # node block
        ],
        out_specs=pl.BlockSpec((block_nodes, F), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((N, F), features.dtype),
        interpret=interpret,
    )(features, nbrs)
