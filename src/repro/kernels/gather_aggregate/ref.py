"""Oracle for the gather-aggregate (padded-neighbor SpMM) kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gather_aggregate_ref(features: jnp.ndarray, nbrs: jnp.ndarray, *,
                         mean: bool = False) -> jnp.ndarray:
    """features: (N, F); nbrs: (N, Dmax) int32 (pad = -1) → (N, F).

    out[i] = Σ_j features[nbrs[i, j]]  (masked), optionally degree-mean.
    """
    mask = nbrs >= 0
    rows = jnp.take(features, jnp.maximum(nbrs, 0), axis=0)  # (N, Dmax, F)
    rows = jnp.where(mask[..., None], rows, 0)
    out = rows.sum(axis=1)
    if mean:
        out = out / jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
    return out
