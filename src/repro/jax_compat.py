"""Version tolerance for the jax APIs this repo uses.

The code targets current jax (explicit-sharding era: ``jax.sharding.AxisType``,
``jax.shard_map`` with ``check_vma``), but frozen containers may carry an older
release where those names live elsewhere or don't exist.  Everything that
depends on a moved/renamed symbol goes through this module so the rest of the
codebase can stay on the modern spelling.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def _accepts_kwarg(fn, name: str) -> bool:
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False

__all__ = ["auto_axis_types", "axis_size", "make_mesh", "make_raw_mesh",
           "shard_map"]


def axis_size(axis_name):
    """jax.lax.axis_size, or the psum(1) idiom where it doesn't exist yet."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def auto_axis_types(n_axes: int) -> Optional[tuple]:
    """(AxisType.Auto,) * n on modern jax; None where AxisType predates."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> Mesh:
    """jax.make_mesh with Auto axis types where the API supports them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_types = auto_axis_types(len(tuple(axis_names)))
    if axis_types is not None and _accepts_kwarg(jax.make_mesh, "axis_types"):
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def make_raw_mesh(devices, axis_names: Sequence[str]) -> Mesh:
    """jax.sharding.Mesh from an explicit device array, version-tolerant."""
    axis_types = auto_axis_types(len(tuple(axis_names)))
    if axis_types is not None and _accepts_kwarg(Mesh.__init__, "axis_types"):
        return Mesh(devices, tuple(axis_names), axis_types=axis_types)
    return Mesh(devices, tuple(axis_names))


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map / jax.experimental.shard_map across jax versions.

    ``check_vma`` maps onto the old ``check_rep`` (same semantics: verify
    per-output replication/varying-axis annotations).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    if _accepts_kwarg(_shard_map, "check_rep"):
        kw = {"check_rep": check_vma}
    elif _accepts_kwarg(_shard_map, "check_vma"):
        kw = {"check_vma": check_vma}
    else:
        kw = {}
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs, **kw)
