"""Paper Fig 13 — λ (slider) sweep: time and #frequent patterns."""
from __future__ import annotations

from .common import emit, run_mine

LAMBDAS = (0.0, 0.2, 0.4, 0.6, 0.8, 1.0)


def main() -> None:
    rows = []
    for lam in LAMBDAS:
        res = run_mine("gnutella", sigma=8, lam=lam, metric="mis")
        rows.append({
            "name": f"slider/gnutella/lam{lam}",
            "us_per_call": round(res.elapsed_s * 1e6, 1),
            "derived": len(res.frequent),
            "searched": res.searched,
        })
    emit(rows, ["name", "us_per_call", "derived", "searched"])


if __name__ == "__main__":
    main()
