"""Paper Table 2 + Fig 12 — searched/frequent pattern counts per metric.

|S_g| (MNI via edge extension), |S_f| (mIS via merging), |S_t| (fractional)
across support values."""
from __future__ import annotations

from .common import emit, run_mine

SUPPORTS = (6, 8, 10, 12)


def main() -> None:
    rows = []
    for sigma in SUPPORTS:
        sg = run_mine("gnutella", sigma=sigma, metric="mni",
                      generation="edge_ext")
        sf = run_mine("gnutella", sigma=sigma, metric="mis", lam=0.5)
        st = run_mine("gnutella", sigma=sigma, metric="frac",
                      generation="edge_ext")
        rows.append({
            "name": f"patterns/gnutella/s{sigma}",
            "us_per_call": round((sg.elapsed_s + sf.elapsed_s + st.elapsed_s) * 1e6, 1),
            "derived": sf.searched,
            "S_g": sg.searched, "S_f": sf.searched, "S_t": st.searched,
            "F_g": len(sg.frequent), "F_f": len(sf.frequent),
            "F_t": len(st.frequent),
        })
    emit(rows, ["name", "us_per_call", "derived", "S_g", "S_f", "S_t",
                "F_g", "F_f", "F_t"])


if __name__ == "__main__":
    main()
