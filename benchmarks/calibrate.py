"""Micro-calibration of the execution planner's cost model.

    PYTHONPATH=src python -m benchmarks.calibrate [--out planner_calibration.json]

Fits the four `repro.core.planner.CostModel` constants on the current
backend by timing the *actual* ``mis`` step program at controlled
geometries.  The model has two separate work terms —

    t = dispatch_overhead_s + lanes·lane_time_s + cap·row_time_s

(expansion-grid lanes vs the per-frontier-row metric scan) — and the
probes are chosen so each constant is isolated:

  * ``lane_time_s`` — same cap, chunk 4 vs 64 (``max_chunks`` pinned to
    1): only the lane count moves, the scan term cancels;
  * ``row_time_s`` — same chunk, cap 512 vs 4096: the lane term is
    subtracted with the fitted ``lane_time_s``, what remains scales with
    cap (on CPU the greedy-mIS ``lax.scan`` dominates here);
  * ``dispatch_overhead_s`` — the small-geometry timing minus both fitted
    work terms (includes host↔device sync, i.e. what the sequential
    loop pays per block);
  * ``vmap_factor`` — per-pattern work of a bucket-4 vmapped step over 4×
    the unbatched work: XLA loses cross-op fusion on batched grids, and
    this tax is what tips compute-bound levels back to sequential.

Schema 2 adds per-metric scan constants: the cap-scaling probe pair is
re-timed under ``mni``, ``frac`` and ``mis_luby`` (the expansion-grid
lane term is metric-independent, so the fitted ``lane_time_s`` is
subtracted as-is) and the residuals land in ``row_time_{mni,frac,luby}_s``
— `CostModel.row_time(metric)` falls back to the ``mis`` constant for
anything unprobed, so schema-1 files keep loading.

Schema 3 adds ``escalation_fraction`` — the measured fraction of sampled
patterns that escalated to the exact pass, folded in after each launch
run by `repro.core.planner.persist_escalation_fraction` (this fit writes
``None`` on a fresh file and preserves any existing measurement);
`CostModel.esc_prior()` uses it to warm-start the auto planner's
sampled-plane pricing when a level has no telemetry of its own.

The result is a tiny JSON (`planner_calibration.json` by default — the
file `repro.core.planner.load_calibration` picks up from the working
directory or ``$REPRO_PLANNER_CALIBRATION``).  ``benchmarks/run.py``
runs this pass automatically in ``--smoke`` mode so a fresh checkout's
first bench sweep also refreshes the planner constants.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import numpy as np


def _time_calls(fn, iters: int) -> float:
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def fit_cost_model(iters: int = 20) -> dict:
    """Measure the step program and return a CostModel dict (schema 2)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import MatchConfig, build_graph, initial_candidates
    from repro.core.batched import _state_init, _step_fn
    from repro.core.graph import DeviceGraph
    from repro.core.plan import make_plan, stack_plans
    from repro.core.planner import CALIBRATION_SCHEMA, CostModel

    rng = np.random.default_rng(0)
    n, deg = 4096, 3
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    g = build_graph(n, np.stack([src, dst], 1), rng.integers(0, 4, n),
                    undirected=True)
    dev_g = DeviceGraph.from_host(g)
    pats = initial_candidates(g)[:4]
    plans = [make_plan(p, g) for p in pats]
    k = pats[0].k

    def step_time(cap: int, chunk: int, bucket: int,
                  metric: str = "mis") -> float:
        # max_chunks pinned to 1 so lanes == cap·chunk exactly (timing
        # probe only — truncated candidate enumeration is fine here)
        cfg = dataclasses.replace(
            MatchConfig.for_graph(g, cap=cap, root_block=1024),
            chunk=chunk, max_chunks=1, two_phase=False)
        step = _step_fn(metric, k, cfg, unbatched=bucket == 1)
        sel = [plans[i % len(plans)] for i in range(bucket)]
        stacked = stack_plans(sel)
        state = _state_init(metric, bucket, k, n)
        taus = jnp.full((bucket,), 10**9, jnp.int32)

        def call():
            out = step(dev_g, stacked, jnp.int32(0), state, taus)
            jax.block_until_ready(out[1])

        return _time_calls(call, iters)

    CAP_S, CAP_B, CH_S, CH_B = 512, 4096, 4, 64
    t_ss = step_time(CAP_S, CH_S, 1)      # small cap, small chunk
    t_sb = step_time(CAP_S, CH_B, 1)      # small cap, big chunk
    t_bs = step_time(CAP_B, CH_S, 1)      # big cap, small chunk

    # lanes = (k-1)·cap·chunk with max_chunks == 1
    lane_time = max((t_sb - t_ss) / ((k - 1) * CAP_S * (CH_B - CH_S)),
                    1e-12)
    row_time = max(
        (t_bs - t_ss - (k - 1) * (CAP_B - CAP_S) * CH_S * lane_time)
        / (CAP_B - CAP_S), 1e-12)
    overhead = max(
        t_ss - (k - 1) * CAP_S * CH_S * lane_time - CAP_S * row_time, 1e-6)

    # the fusion tax shows on WIDE grids (the scan term vmaps fine): fit
    # it where the lane term dominates
    work_bb = (k - 1) * CAP_B * CH_B * lane_time + CAP_B * row_time
    t_vmap4 = step_time(CAP_B, CH_B, 4)
    vmap_factor = max(1.0, (t_vmap4 - overhead) / (4 * work_bb))

    # per-metric scan constants: same cap pair, lane term cancelled with
    # the mis-fitted lane_time (the expansion grid is metric-independent)
    lane_delta = (k - 1) * (CAP_B - CAP_S) * CH_S * lane_time
    metric_rows, metric_probe = {}, {}
    for metric, key in (("mni", "row_time_mni_s"),
                        ("frac", "row_time_frac_s"),
                        ("mis_luby", "row_time_luby_s")):
        t_s_m = step_time(CAP_S, CH_S, 1, metric)
        t_b_m = step_time(CAP_B, CH_S, 1, metric)
        metric_rows[key] = float(
            max((t_b_m - t_s_m - lane_delta) / (CAP_B - CAP_S), 1e-12))
        metric_probe[f"t_cap4096_ch4_{metric}"] = round(t_b_m, 6)

    return {
        "schema": CALIBRATION_SCHEMA,
        "dispatch_overhead_s": float(overhead),
        "lane_time_s": float(lane_time),
        "row_time_s": float(row_time),
        **metric_rows,
        # schema 3: measured per-run escalation fraction — not a timing
        # probe; `repro.launch.mine` folds the observed value in after
        # each sampled run (`planner.persist_escalation_fraction`) and
        # `write_calibration` carries any existing measurement forward
        "escalation_fraction": None,
        "vmap_factor": float(round(vmap_factor, 3)),
        "backend": jax.default_backend(),
        "source": "benchmarks/calibrate.py",
        "probe": {
            "n": n, "k": k,
            "t_cap512_ch4": round(t_ss, 6),
            "t_cap512_ch64": round(t_sb, 6),
            "t_cap4096_ch4": round(t_bs, 6),
            "t_cap4096_ch64_vmap4": round(t_vmap4, 6),
            **metric_probe,
        },
        # keep the defaults' semantics documented next to the numbers
        "_model": "t_step = dispatch_overhead_s + bucket * ((k-1)*cap*chunk"
                  "*max_chunks*lane_time_s + cap*row_time_s)"
                  " * (vmap_factor if bucket>1)",
    }


def write_calibration(out: Optional[str] = None, iters: int = 20) -> str:
    from repro.core.planner import DEFAULT_CALIBRATION_FILE

    out = out or DEFAULT_CALIBRATION_FILE
    model = fit_cost_model(iters=iters)
    try:
        # a re-fit refreshes the timing constants but must not discard the
        # mining-measured escalation fraction accumulated by launch runs
        with open(out) as f:
            prev = json.load(f).get("escalation_fraction")
        if isinstance(prev, (int, float)):
            model["escalation_fraction"] = float(prev)
    except (OSError, ValueError):
        pass
    with open(out, "w") as f:
        json.dump(model, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# planner calibration → {out}: "
          f"overhead={model['dispatch_overhead_s'] * 1e6:.0f}us "
          f"lane={model['lane_time_s'] * 1e9:.3f}ns "
          f"row={model['row_time_s'] * 1e6:.3f}us "
          f"(mni {model['row_time_mni_s'] * 1e6:.3f} / "
          f"frac {model['row_time_frac_s'] * 1e6:.3f} / "
          f"luby {model['row_time_luby_s'] * 1e6:.3f}) "
          f"vmap_factor={model['vmap_factor']:.2f}")
    return out


def main() -> None:
    write_calibration()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()
    write_calibration(args.out, iters=args.iters)
    sys.exit(0)
