"""Paper Fig 11 — peak memory vs support across variants."""
from __future__ import annotations

from .common import BENCH_DATASETS, emit, run_mine

SUPPORTS = (6, 10)


def main() -> None:
    rows = []
    for ds in BENCH_DATASETS:
        for sigma in SUPPORTS:
            for name, kw in [
                ("flexis_0.4", dict(metric="mis", lam=0.4)),
                ("mni_edge_ext", dict(metric="mni", generation="edge_ext")),
                ("frac_edge_ext", dict(metric="frac", generation="edge_ext")),
            ]:
                res = run_mine(ds, sigma=sigma, **kw)
                rows.append({
                    "name": f"memory/{ds}/s{sigma}/{name}",
                    "us_per_call": round(res.elapsed_s * 1e6, 1),
                    "derived": res.peak_device_bytes,
                })
    emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
