"""Benchmark harness driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke] [--out CSV]

Emits ``name,us_per_call,derived[,...]`` CSV blocks per benchmark.  Exits
nonzero if any benchmark module fails (or ``--only`` matches nothing).
``--smoke`` collapses dataset scales/iteration counts to CI-budget sizes;
``--out`` additionally tees all output to a CSV file (the CI smoke job
uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import os
import sys
import time


BENCHES = [
    ("execution_time (Fig 9/10)", "benchmarks.bench_execution_time"),
    ("memory (Fig 11)", "benchmarks.bench_memory"),
    ("patterns (Table 2 / Fig 12)", "benchmarks.bench_patterns"),
    ("slider (Fig 13)", "benchmarks.bench_slider"),
    ("similarity (Table 3)", "benchmarks.bench_similarity"),
    ("kernels", "benchmarks.bench_kernels"),
]


class _Tee(io.TextIOBase):
    def __init__(self, *streams):
        self._streams = streams

    def write(self, s):
        for st in self._streams:
            st.write(s)
        return len(s)

    def flush(self):
        for st in self._streams:
            st.flush()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / few iters (CI smoke job)")
    ap.add_argument("--out", default=None,
                    help="also write all output to this CSV file")
    args = ap.parse_args(argv)

    if args.smoke:
        # must be set before benchmark modules import benchmarks.common
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    import importlib

    out_file = open(args.out, "w") if args.out else None
    stdout = _Tee(sys.stdout, out_file) if out_file else sys.stdout

    failures = 0
    matched = 0
    with contextlib.redirect_stdout(stdout):
        for label, modname in BENCHES:
            if args.only and args.only not in modname:
                continue
            matched += 1
            print(f"# === {label} [{modname}] ===", flush=True)
            t0 = time.monotonic()
            try:
                importlib.import_module(modname).main()
            except Exception as e:  # surface but keep going
                failures += 1
                print(f"# FAILED: {e!r}", flush=True)
            print(f"# ({time.monotonic() - t0:.1f}s)", flush=True)
        if args.only and matched == 0:
            print(f"# ERROR: --only {args.only!r} matched no benchmark",
                  flush=True)
            failures += 1
    if out_file:
        out_file.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
