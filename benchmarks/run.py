"""Benchmark harness driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Emits ``name,us_per_call,derived[,...]`` CSV blocks per benchmark.
"""
from __future__ import annotations

import argparse
import sys
import time


BENCHES = [
    ("execution_time (Fig 9/10)", "benchmarks.bench_execution_time"),
    ("memory (Fig 11)", "benchmarks.bench_memory"),
    ("patterns (Table 2 / Fig 12)", "benchmarks.bench_patterns"),
    ("slider (Fig 13)", "benchmarks.bench_slider"),
    ("similarity (Table 3)", "benchmarks.bench_similarity"),
    ("kernels", "benchmarks.bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    import importlib

    failures = 0
    for label, modname in BENCHES:
        if args.only and args.only not in modname:
            continue
        print(f"# === {label} [{modname}] ===", flush=True)
        t0 = time.monotonic()
        try:
            importlib.import_module(modname).main()
        except Exception as e:  # surface but keep going
            failures += 1
            print(f"# FAILED: {e!r}", flush=True)
        print(f"# ({time.monotonic() - t0:.1f}s)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
