"""Benchmark harness driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke] [--out CSV]

Emits ``name,us_per_call,derived[,...]`` CSV blocks per benchmark.  Exits
nonzero if any benchmark module fails (or ``--only`` matches nothing).
``--smoke`` collapses dataset scales/iteration counts to CI-budget sizes
and additionally writes ``BENCH_smoke.json`` at the repo root — a stable
machine-readable trajectory point (per-row name/us_per_call/parity plus
per-module wall time) successive PRs can diff; ``--out`` tees all output
to a CSV file (the CI smoke job uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import time
from pathlib import Path


BENCHES = [
    ("execution_time (Fig 9/10)", "benchmarks.bench_execution_time"),
    ("memory (Fig 11)", "benchmarks.bench_memory"),
    ("patterns (Table 2 / Fig 12)", "benchmarks.bench_patterns"),
    ("slider (Fig 13)", "benchmarks.bench_slider"),
    ("similarity (Table 3)", "benchmarks.bench_similarity"),
    ("kernels", "benchmarks.bench_kernels"),
]


class _Tee(io.TextIOBase):
    def __init__(self, *streams):
        self._streams = streams

    def write(self, s):
        for st in self._streams:
            st.write(s)
        return len(s)

    def flush(self):
        for st in self._streams:
            st.flush()


class _RowCollector(io.TextIOBase):
    """Parse the CSV convention out of the printed stream.

    ``emit`` prints a header line (first cell ``name``) then rows; comment
    lines start with ``#``.  Collected rows become the stable
    ``BENCH_smoke.json`` entries: name, us_per_call, derived, and parity —
    ``derived`` is each row family's own figure of merit (speedup,
    GFLOP/s, counts …); for the plane-equivalence families
    (``exec_time/expansion_plane/*``, ``kernel/frontier_expand_pallas*``)
    it is the bit-exactness indicator and is surfaced as ``parity``
    (1.0 = bit-exact), null elsewhere.  ``exec_time/sampled/*`` and
    ``exec_time/auto_sampled/*`` rows additionally carry their own
    ``accuracy`` column (1.0 = frequent set identical to the
    forced-batched oracle) — persisted so the regression gate can fail
    on exactness loss, not just latency.
    """

    _PARITY_FAMILIES = ("exec_time/expansion_plane/",
                        "kernel/frontier_expand_pallas")

    def __init__(self):
        self.rows = []
        self._cols = None
        self._buf = ""

    def write(self, s):
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            self._line(line.strip())
        return len(s)

    def _line(self, line):
        if not line or line.startswith("#") or "," not in line:
            return
        cells = [c.strip() for c in line.split(",")]
        if cells[0] == "name":
            self._cols = cells
            return
        if self._cols is None or len(cells) != len(self._cols):
            return
        row = dict(zip(self._cols, cells))
        try:
            us = float(row.get("us_per_call", "nan"))
        except ValueError:
            return
        try:
            derived = float(row.get("derived", "nan"))
        except ValueError:
            derived = float("nan")
        derived_ok = derived == derived  # not NaN
        is_parity = row["name"].startswith(self._PARITY_FAMILIES)
        entry = {
            "name": row["name"],
            "us_per_call": us,
            "derived": derived if derived_ok else None,
            "parity": derived if (derived_ok and is_parity) else None,
        }
        try:
            entry["accuracy"] = float(row["accuracy"])
        except (KeyError, ValueError):
            pass  # rows without an accuracy column stay schema-compatible
        self.rows.append(entry)


def _env_stamp() -> dict:
    """Host/runtime provenance stamped into the trajectory file.

    Two smoke points only diff meaningfully when they ran on comparable
    hardware; the stamp lets the regression gate's reader (and a human
    reading the JSON) tell a real regression from a host change.
    """
    import platform
    import socket

    env = {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax

        env["jax_version"] = jax.__version__
        env["device_count"] = jax.device_count()
        env["device_platforms"] = sorted({d.platform for d in jax.devices()})
    except Exception:  # trajectory must still be written on a broken jax
        env["jax_version"] = None
        env["device_count"] = 0
        env["device_platforms"] = []
    return env


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / few iters (CI smoke job)")
    ap.add_argument("--out", default=None,
                    help="also write all output to this CSV file")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the planner micro-calibration pass "
                         "(benchmarks/calibrate.py) before the benchmarks "
                         "and write planner_calibration.json; --smoke "
                         "always runs it")
    args = ap.parse_args(argv)

    if args.smoke:
        # must be set before benchmark modules import benchmarks.common
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    # the trajectory file is only meaningful for a *full* smoke sweep — a
    # partial --only run must not overwrite it with a subset of the rows
    write_trajectory = args.smoke and not args.only

    import importlib

    out_file = open(args.out, "w") if args.out else None
    collector = _RowCollector() if write_trajectory else None
    streams = [sys.stdout]
    if out_file:
        streams.append(out_file)
    if collector:
        streams.append(collector)
    stdout = _Tee(*streams) if len(streams) > 1 else sys.stdout

    failures = 0
    matched = 0
    modules = []
    with contextlib.redirect_stdout(stdout):
        if args.calibrate or (args.smoke and not args.only):
            # planner cost-model fit: constants the execution planner loads
            # (repro.core.planner.load_calibration); smoke keeps it cheap
            print("# === planner calibration [benchmarks.calibrate] ===",
                  flush=True)
            t0 = time.monotonic()
            try:
                from benchmarks.calibrate import write_calibration

                write_calibration(iters=3 if args.smoke else 20)
                ok = True
            except Exception as e:
                failures += 1
                ok = False
                print(f"# FAILED: {e!r}", flush=True)
            wall = time.monotonic() - t0
            modules.append({"module": "benchmarks.calibrate",
                            "wall_s": round(wall, 3), "ok": ok})
            print(f"# ({wall:.1f}s)", flush=True)
        for label, modname in BENCHES:
            if args.only and args.only not in modname:
                continue
            matched += 1
            print(f"# === {label} [{modname}] ===", flush=True)
            t0 = time.monotonic()
            ok = True
            try:
                importlib.import_module(modname).main()
            except Exception as e:  # surface but keep going
                failures += 1
                ok = False
                print(f"# FAILED: {e!r}", flush=True)
            wall = time.monotonic() - t0
            modules.append({"module": modname, "wall_s": round(wall, 3),
                            "ok": ok})
            print(f"# ({wall:.1f}s)", flush=True)
        if args.only and matched == 0:
            print(f"# ERROR: --only {args.only!r} matched no benchmark",
                  flush=True)
            failures += 1
    if out_file:
        out_file.close()
    if collector is not None:
        # the perf-trajectory point successive PRs diff (stable schema)
        trajectory = {
            "schema": 1,
            "smoke": True,
            "env": _env_stamp(),
            "failures": failures,
            "modules": modules,
            "rows": collector.rows,
        }
        path = Path(__file__).resolve().parent.parent / "BENCH_smoke.json"
        path.write_text(json.dumps(trajectory, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {path}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
