"""Kernel micro-benchmarks (interpret-mode correctness + jnp-path timing).

Wall-clock on this CPU container times the *jnp oracle paths* (the
production CPU fallbacks); the Pallas kernels themselves are TPU-targeted
and validated for correctness in interpret mode (see tests/kernels)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import SMOKE, bench_iters, emit


def _time(fn, *args, iters=20):
    iters = bench_iters(iters)
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        out = out[0] if isinstance(out, tuple) else out
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    rng = np.random.default_rng(0)
    rows = []

    # chunked attention (flash oracle)
    from repro.models.attention import AttentionConfig, _attn_chunked

    B, S, H, KV, hd = 1, 1024, 8, 4, 64
    cfg = AttentionConfig(d_model=H * hd, n_heads=H, n_kv_heads=KV, head_dim=hd)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: _attn_chunked(q, k, v, cfg, 256, 256))
    us = _time(f, q, k, v)
    flops = 4 * B * S * S * H * hd / 2
    rows.append({"name": f"kernel/attn_chunked/B{B}S{S}H{H}",
                 "us_per_call": round(us, 1),
                 "derived": round(flops / (us * 1e-6) / 1e9, 2)})  # GFLOP/s

    # mIS greedy scan (production path)
    from repro.core.mis import bitmap_init, mis_greedy_update

    n, cap, kk = 100_000, 8192, 4
    emb = np.stack([rng.choice(n, kk, replace=False) for _ in range(cap)]).astype(np.int32)
    bm = bitmap_init(n)
    g = jax.jit(lambda bm, e: mis_greedy_update(bm, jnp.int32(0), e,
                                                jnp.int32(cap),
                                                jnp.int32(10**9), kk))
    us = _time(g, bm, jnp.asarray(emb))
    rows.append({"name": f"kernel/mis_greedy/cap{cap}k{kk}",
                 "us_per_call": round(us, 1),
                 "derived": round(cap / (us * 1e-6) / 1e6, 3)})  # M emb/s

    # Luby parallel rounds
    from repro.core.mis import mis_luby_update

    h = jax.jit(lambda bm, e: mis_luby_update(bm, jnp.int32(0), e,
                                              jnp.int32(cap),
                                              jnp.int32(10**9), kk, n))
    us = _time(h, bm, jnp.asarray(emb))
    rows.append({"name": f"kernel/mis_luby/cap{cap}k{kk}",
                 "us_per_call": round(us, 1),
                 "derived": round(cap / (us * 1e-6) / 1e6, 3)})

    # batched level step (PR 1 data plane): one vmapped program for a
    # 16-pattern candidate batch vs 16 single-pattern dispatches
    from repro.core import MatchConfig, build_graph
    from repro.core.batched import _state_init, _step_fn
    from repro.core.flexis import initial_candidates
    from repro.core.graph import DeviceGraph
    from repro.core.matcher import match_block
    from repro.core.mis import bitmap_init, mis_greedy_update as mgu
    from repro.core.plan import make_plan, stack_plans

    bn = 1000 if SMOKE else 4000
    src = np.repeat(np.arange(bn), 2)
    dst = rng.integers(0, bn, bn * 2)
    bg = build_graph(bn, np.stack([src, dst], 1),
                     rng.integers(0, 8, bn), undirected=True)
    dev_bg = DeviceGraph.from_host(bg)
    mcfg = MatchConfig.for_graph(bg, cap=64, root_block=64)
    pats = initial_candidates(bg)[:16]
    plans = [make_plan(p, bg) for p in pats]
    stacked = stack_plans(plans)
    state = _state_init("mis", 16, 2, bn)
    taus16 = jnp.full((16,), 10**9, jnp.int32)
    step = _step_fn("mis", 2, mcfg)
    us_b = _time(lambda: step(dev_bg, stacked, jnp.int32(0), state, taus16)[1])

    def _sixteen_singles():
        c = jnp.int32(0)
        for plan in plans:
            emb, n_valid, _, _, _ = match_block(dev_bg, plan, jnp.int32(0), mcfg)
            _, c = mgu(bitmap_init(bn), jnp.int32(0), emb, n_valid,
                       jnp.int32(10**9), 2)
        return c

    us_s = _time(_sixteen_singles)
    rows.append({"name": "kernel/batched_step/P16",
                 "us_per_call": round(us_b, 1),
                 "derived": round(us_s / us_b, 2)})  # speedup vs 16 singles

    # fused frontier expansion (PR 2): whole match_block through the XLA
    # pipeline (production CPU path) vs the fused Pallas kernel.  On this
    # CPU container the kernel runs in interpret mode, so its wall-clock is
    # not the hardware number — the row documents *bit-exact parity*
    # (derived=1.0) per the acceptance contract; on TPU pass
    # pallas_interpret=False to measure the fused kernel itself.
    import dataclasses as _dc

    from repro.core import MatchConfig, build_graph
    from repro.core.flexis import initial_candidates
    from repro.core.generation import generate_new_patterns
    from repro.core.graph import DeviceGraph
    from repro.core.matcher import match_block
    from repro.core.plan import make_plan as _make_plan

    fn_n = 500 if SMOKE else 4000
    fdeg = 4
    fsrc = np.repeat(np.arange(fn_n), fdeg)
    fdst = rng.integers(0, fn_n, fn_n * fdeg)
    fg = build_graph(fn_n, np.stack([fsrc, fdst], 1),
                     rng.integers(0, 4, fn_n), undirected=True)
    fdev = DeviceGraph.from_host(fg)
    fcfg = _dc.replace(
        MatchConfig.for_graph(fg, cap=256 if SMOKE else 2048,
                              root_block=256),
        two_phase=False)
    fcfg_p = _dc.replace(fcfg, expansion="pallas")
    fpats = initial_candidates(fg)
    fk3 = generate_new_patterns(fpats[:6])
    assert fk3, "graph yields no size-3 candidates"
    fplan = _make_plan(fk3[0], fg)
    assert fplan.k == 3

    geo = f"cap{fcfg.cap}C{fcfg.chunk}k{fplan.k}"
    xla_out = match_block(fdev, fplan, jnp.int32(0), fcfg)
    pal_out = match_block(fdev, fplan, jnp.int32(0), fcfg_p)
    parity = float(
        int(xla_out[1]) == int(pal_out[1])
        and int(xla_out[2]) == int(pal_out[2])
        and bool(xla_out[3]) == bool(pal_out[3])
        and bool(np.array_equal(np.asarray(xla_out[0]),
                                np.asarray(pal_out[0]))))
    cands_per_call = fcfg.cap * fcfg.chunk * fcfg.max_chunks * (fplan.k - 1)
    us = _time(lambda: match_block(fdev, fplan, jnp.int32(0), fcfg), iters=10)
    rows.append({"name": f"kernel/frontier_expand_xla/{geo}",
                 "us_per_call": round(us, 1),
                 "derived": round(cands_per_call / (us * 1e-6) / 1e6, 2)})  # M cand/s
    us_p = _time(lambda: match_block(fdev, fplan, jnp.int32(0), fcfg_p),
                 iters=2 if SMOKE else 5)
    rows.append({"name": f"kernel/frontier_expand_pallas_interp/{geo}",
                 "us_per_call": round(us_p, 1),
                 "derived": parity})  # 1.0 = bit-exact parity with xla plane

    # embedding bag (jnp path)
    from repro.models.embedding import embedding_bag_apply, embedding_bag_init

    tbl = embedding_bag_init(jax.random.key(0), 1_000_00, 64)
    idx = jnp.asarray(rng.integers(0, 1_000_00, (8192, 4)), jnp.int32)
    eb = jax.jit(lambda t, i: embedding_bag_apply(t, i))
    us = _time(eb, tbl, idx)
    rows.append({"name": "kernel/embedding_bag/B8192H4D64",
                 "us_per_call": round(us, 1),
                 "derived": round(8192 * 4 / (us * 1e-6) / 1e6, 2)})  # M lookups/s

    # segment-sum GNN aggregation (jnp path)
    from repro.models.gnn.common import scatter_sum

    E, N, F = 100_000, 10_000, 128
    msgs = jnp.asarray(rng.normal(size=(E, F)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    mask = jnp.ones((E,), bool)
    ss = jax.jit(lambda m, d: scatter_sum(m, d, mask, N))
    us = _time(ss, msgs, dst)
    rows.append({"name": f"kernel/scatter_sum/E{E}F{F}",
                 "us_per_call": round(us, 1),
                 "derived": round(E * F * 4 / (us * 1e-6) / 2**30, 2)})  # GiB/s

    emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
