"""Paper Table 3 — pattern-set overlap between FLEXIS and the baselines
(canonical-form isomorphism intersection), per pattern size."""
from __future__ import annotations

from collections import defaultdict

from repro.core import canonical_key

from .common import emit, run_mine


def main() -> None:
    sigma = 8
    f_mis = run_mine("gnutella", sigma=sigma, metric="mis", lam=0.4)
    f_mni = run_mine("gnutella", sigma=sigma, metric="mni",
                     generation="edge_ext")
    f_frac = run_mine("gnutella", sigma=sigma, metric="frac",
                      generation="edge_ext")

    def by_k(res):
        d = defaultdict(set)
        for p, _ in res.frequent:
            d[p.k].add(canonical_key(p))
        return d

    mis_k, mni_k, frac_k = by_k(f_mis), by_k(f_mni), by_k(f_frac)
    rows = []
    for k in sorted(set(mis_k) | set(mni_k) | set(frac_k)):
        ff, fg, ft = mis_k.get(k, set()), mni_k.get(k, set()), frac_k.get(k, set())
        rows.append({
            "name": f"similarity/gnutella/s{sigma}/k{k}",
            "us_per_call": 0.0,
            "derived": len(ff & fg),
            "f_f": len(ff), "f_g": len(fg), "f_t": len(ft),
            "ff_and_fg": len(ff & fg), "ff_and_ft": len(ff & ft),
        })
    emit(rows, ["name", "us_per_call", "derived", "f_f", "f_g", "f_t",
                "ff_and_fg", "ff_and_ft"])


if __name__ == "__main__":
    main()
