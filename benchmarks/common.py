"""Shared benchmark plumbing: timed mining runs + CSV emission.

Every benchmark mirrors one paper artifact (DESIGN.md §7) on structure-
matched synthetic stand-ins (scaled; labels were random in the paper too).
CSV convention: ``name,us_per_call,derived`` per the harness contract, with
additional artifact-specific columns after.

Smoke mode (``benchmarks.run --smoke``, or env ``REPRO_BENCH_SMOKE=1``):
tiny dataset scales and iteration counts so the whole harness finishes in
CI-budget minutes — used by the non-blocking CI smoke job.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.core import MatchConfig, MiningConfig, mine
from repro.core.flexis import MiningResult
from repro.data.synthetic import paper_dataset

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

# benches must run in CI-ish time on 1 CPU core: scaled datasets
BENCH_SCALE = 0.005 if SMOKE else 0.02
BENCH_DATASETS = ("gnutella",) if SMOKE else ("gnutella", "wiki-vote")
BENCH_MAX_SIZE = 3


def bench_iters(full: int, smoke: int = 2) -> int:
    """Iteration count for timing loops, collapsed in smoke mode."""
    return smoke if SMOKE else full


def run_mine(dataset: str, *, sigma: int, lam: float = 0.4,
             metric: str = "mis", generation: str = "merge",
             scale: Optional[float] = None, max_size: int = BENCH_MAX_SIZE,
             complete: bool = False, time_limit: float = 120.0,
             execution: str = "auto", seed: int = 0) -> MiningResult:
    scale = BENCH_SCALE if scale is None else scale
    g = paper_dataset(dataset, scale=scale, seed=seed)
    cfg = MiningConfig(
        sigma=sigma, lam=lam, metric=metric, generation=generation,
        max_pattern_size=max_size, complete=complete,
        time_limit_s=time_limit, execution=execution,
        match=MatchConfig.for_graph(g, cap=4096))
    return mine(g, cfg)


def emit(rows: List[Dict], header: Optional[List[str]] = None):
    if not rows:
        return
    cols = header or list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
