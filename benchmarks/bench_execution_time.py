"""Paper Fig 9/10 — execution time vs support, FLEXIS (λ sweep) vs the
MNI (GraMi-like) and fractional (T-FSM-like) baselines, same runtime —
plus the batched-vs-sequential data-plane comparison (PR 1 tentpole):
host-loop wall time for one level of ≥ 16 same-k candidates.
"""
from __future__ import annotations

import time

import numpy as np

from .common import BENCH_DATASETS, SMOKE, bench_iters, emit, run_mine

SUPPORTS = (6,) if SMOKE else (6, 8, 12)
VARIANTS = [
    ("flexis_0.4", dict(metric="mis", lam=0.4, generation="merge")),
    ("flexis_1.0", dict(metric="mis", lam=1.0, generation="merge")),
    ("mni_edge_ext(GraMi-like)", dict(metric="mni", generation="edge_ext")),
    ("frac_edge_ext(T-FSM-like)", dict(metric="frac", generation="edge_ext")),
]


def _bounded_degree_graph(n: int, deg: int, n_labels: int, seed: int = 0):
    """No hubs ⇒ MatchConfig.for_graph right-sizes to a small-work geometry
    where per-block device compute is tiny and the host loop (dispatch +
    per-block sync) dominates — the regime the batched plane amortizes."""
    from repro.core import build_graph

    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    labels = rng.integers(0, n_labels, n)
    return build_graph(n, np.stack([src, dst], 1), labels, undirected=True)


def _bench_batched_level(rows):
    from repro.core import MatchConfig, MiningConfig
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import evaluate_pattern, initial_candidates, tau_threshold
    from repro.core.graph import DeviceGraph

    n = 2000 if SMOKE else 8000
    g = _bounded_degree_graph(n, deg=2, n_labels=8)
    dev_g = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=64, root_block=64)
    reps = bench_iters(3, smoke=1)

    for P in (16, 32):
        cands = initial_candidates(g)[:P]
        assert len(cands) == P, f"graph yields only {len(cands)} candidates"
        taus = [tau_threshold(8, 1.0, p.k) for p in cands]
        seq_cfg = MiningConfig(sigma=8, lam=1.0, metric="mis", complete=True,
                               match=cfg, execution="sequential")

        # warmup compiles both data planes
        seq = [evaluate_pattern(g, dev_g, p, t, seq_cfg)
               for p, t in zip(cands, taus)]
        bat, _, _ = evaluate_level_batched(
            g, dev_g, cands, taus, "mis", cfg, complete=True)
        assert [s.support for s in seq] == [o.support for o in bat]

        t0 = time.perf_counter()
        for _ in range(reps):
            for p, t in zip(cands, taus):
                evaluate_pattern(g, dev_g, p, t, seq_cfg)
        t_seq = (time.perf_counter() - t0) / reps

        t0 = time.perf_counter()
        for _ in range(reps):
            evaluate_level_batched(g, dev_g, cands, taus, "mis", cfg,
                                   complete=True)
        t_bat = (time.perf_counter() - t0) / reps

        rows.append({
            "name": f"exec_time/batched_level/n{n}/P{P}",
            "us_per_call": round(t_bat * 1e6, 1),
            "derived": round(t_seq / t_bat, 2),   # speedup (x)
            "sequential_us": round(t_seq * 1e6, 1),
            "batched_us": round(t_bat * 1e6, 1),
            "speedup": round(t_seq / t_bat, 2),
        })


def _bench_planner(rows):
    """Execution-planner A/B cells (PR 4 tentpole acceptance).

    Two regimes, both end-to-end ``mine()`` runs so the planner sees real
    per-level telemetry:

      * ``planner/compute_bound_P1`` — single-label bounded-degree graph
        (1–2 candidates per level) under a deliberately oversized
        graph-global geometry (big cap): one pattern's block saturates the
        device, the batched plane has nothing to amortize, and the win
        comes from the planner's occupancy-derived per-level ``cap``.
        Target: auto ≥ 1.3× over forced batched (derived column).
      * ``planner/level_P{16,32}`` — the dispatch-bound regime of the
        PR 1 cells: auto must keep the batched plane's ≥2× win over
        sequential (derived) while staying within 5% of forced batched
        (``vs_best`` ≥ 0.95).
    """
    import dataclasses

    from repro.core import MatchConfig, MiningConfig, mine

    def timed_mine(g, reps, **kw):
        cfg = MiningConfig(**kw)
        res = mine(g, cfg)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = mine(g, cfg)
        return (time.perf_counter() - t0) / reps, res

    reps = bench_iters(3, smoke=1)

    # --- compute-bound: P∈{1,2} candidates, oversized cap -----------------
    n = 2000 if SMOKE else 8000
    g1 = _bounded_degree_graph(n, deg=2, n_labels=1)
    big = dataclasses.replace(
        MatchConfig.for_graph(g1, cap=16384, root_block=256), two_phase=False)
    kw = dict(sigma=4, lam=1.0, metric="mis", max_pattern_size=3,
              complete=True, match=big)
    t = {}
    out = {}
    for ex in ("batched", "sequential", "auto"):
        t[ex], out[ex] = timed_mine(g1, reps, execution=ex, **kw)
    assert ([(p.k, s) for p, s in out["auto"].frequent]
            == [(p.k, s) for p, s in out["batched"].frequent]
            == [(p.k, s) for p, s in out["sequential"].frequent])
    best = min(t["batched"], t["sequential"])
    rows.append({
        "name": f"exec_time/planner/compute_bound_P1/n{n}",
        "us_per_call": round(t["auto"] * 1e6, 1),
        "derived": round(t["batched"] / t["auto"], 2),   # ≥1.3 target
        "sequential_us": round(t["sequential"] * 1e6, 1),
        "batched_us": round(t["batched"] * 1e6, 1),
        "vs_best": round(best / t["auto"], 3),           # ≥0.95 target
    })

    # --- dispatch-bound: the PR 1 P∈{16,32} cells, auto added -------------
    n = 2000 if SMOKE else 8000
    g2 = _bounded_degree_graph(n, deg=2, n_labels=8)
    cfg2 = MatchConfig.for_graph(g2, cap=64, root_block=64)
    for P in (16, 32):
        from repro.core.flexis import initial_candidates

        assert len(initial_candidates(g2)) >= P
        kw = dict(sigma=8, lam=1.0, metric="mis", max_pattern_size=2,
                  complete=True, match=cfg2)
        t = {}
        out = {}
        for ex in ("batched", "sequential", "auto"):
            # max_pattern_size=2 bounds the run to one level of ≥P
            # candidates; slice via batch_patterns like the PR 1 cell
            t[ex], out[ex] = timed_mine(g2, reps, execution=ex,
                                        batch_patterns=P, **kw)
        assert ([(p.k, s) for p, s in out["auto"].frequent]
                == [(p.k, s) for p, s in out["batched"].frequent])
        best = min(t["batched"], t["sequential"])
        rows.append({
            "name": f"exec_time/planner/level_P{P}/n{n}",
            "us_per_call": round(t["auto"] * 1e6, 1),
            "derived": round(t["sequential"] / t["auto"], 2),  # ≥2 target
            "sequential_us": round(t["sequential"] * 1e6, 1),
            "batched_us": round(t["batched"] * 1e6, 1),
            "vs_best": round(best / t["auto"], 3),             # ≥0.95 target
        })


def _bench_expansion_plane(rows):
    """One batched mining level under each expansion plane (PR 2 tentpole).

    `xla` is the production CPU path; `pallas_interp` runs the fused kernel
    in interpret mode (this container has no TPU), so its time is the
    interpreter's, not the hardware's — the row exists to pin *bit-exact
    parity* (parity=1.0) and to give TPU runs a ready-made A/B harness
    (set pallas_interpret=False there).
    """
    import dataclasses

    from repro.core import MatchConfig
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import initial_candidates
    from repro.core.graph import DeviceGraph

    n = 1000 if SMOKE else 4000
    g = _bounded_degree_graph(n, deg=2, n_labels=8)
    dev_g = DeviceGraph.from_host(g)
    cfg_x = dataclasses.replace(
        MatchConfig.for_graph(g, cap=64, root_block=64), two_phase=False)
    cfg_p = dataclasses.replace(cfg_x, expansion="pallas")
    P = 8
    cands = initial_candidates(g)[:P]
    taus = [10**6] * len(cands)
    reps = bench_iters(3, smoke=1)

    outs = {}
    times = {}
    for name, cfg in (("xla", cfg_x), ("pallas_interp", cfg_p)):
        evaluate_level_batched(g, dev_g, cands, taus, "mis", cfg,
                               complete=True)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            outs[name], _, _ = evaluate_level_batched(
                g, dev_g, cands, taus, "mis", cfg, complete=True)
        times[name] = (time.perf_counter() - t0) / reps
    parity = float(all(
        (a.support, a.embeddings_found, a.overflowed)
        == (b.support, b.embeddings_found, b.overflowed)
        for a, b in zip(outs["xla"], outs["pallas_interp"])))
    for name in ("xla", "pallas_interp"):
        rows.append({
            "name": f"exec_time/expansion_plane/{name}/n{n}/P{P}",
            "us_per_call": round(times[name] * 1e6, 1),
            "derived": parity,  # 1.0 = planes bit-identical on this level
            "speedup": round(times["xla"] / times[name], 3),
        })


def main() -> None:
    rows = []
    _bench_batched_level(rows)
    _bench_planner(rows)
    _bench_expansion_plane(rows)
    for ds in BENCH_DATASETS:
        for sigma in SUPPORTS:
            for name, kw in VARIANTS:
                res = run_mine(ds, sigma=sigma, **kw)
                rows.append({
                    "name": f"exec_time/{ds}/s{sigma}/{name}",
                    "us_per_call": round(res.elapsed_s * 1e6, 1),
                    "derived": len(res.frequent),
                    "searched": res.searched,
                    "timed_out": res.timed_out,
                })
    emit(rows, ["name", "us_per_call", "derived", "searched", "timed_out",
                "sequential_us", "batched_us", "speedup", "vs_best"])


if __name__ == "__main__":
    main()
