"""Paper Fig 9/10 — execution time vs support, FLEXIS (λ sweep) vs the
MNI (GraMi-like) and fractional (T-FSM-like) baselines, same runtime —
plus the batched-vs-sequential data-plane comparison (PR 1 tentpole):
host-loop wall time for one level of ≥ 16 same-k candidates.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (BENCH_DATASETS, BENCH_MAX_SIZE, SMOKE, bench_iters,
                     emit, run_mine)

SUPPORTS = (6,) if SMOKE else (6, 8, 12)
VARIANTS = [
    ("flexis_0.4", dict(metric="mis", lam=0.4, generation="merge")),
    ("flexis_1.0", dict(metric="mis", lam=1.0, generation="merge")),
    ("mni_edge_ext(GraMi-like)", dict(metric="mni", generation="edge_ext")),
    ("frac_edge_ext(T-FSM-like)", dict(metric="frac", generation="edge_ext")),
]


def _bounded_degree_graph(n: int, deg: int, n_labels: int, seed: int = 0):
    """No hubs ⇒ MatchConfig.for_graph right-sizes to a small-work geometry
    where per-block device compute is tiny and the host loop (dispatch +
    per-block sync) dominates — the regime the batched plane amortizes."""
    from repro.core import build_graph

    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    labels = rng.integers(0, n_labels, n)
    return build_graph(n, np.stack([src, dst], 1), labels, undirected=True)


def _bench_batched_level(rows):
    from repro.core import MatchConfig, MiningConfig
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import evaluate_pattern, initial_candidates, tau_threshold
    from repro.core.graph import DeviceGraph

    n = 2000 if SMOKE else 8000
    g = _bounded_degree_graph(n, deg=2, n_labels=8)
    dev_g = DeviceGraph.from_host(g)
    cfg = MatchConfig.for_graph(g, cap=64, root_block=64)
    reps = bench_iters(3, smoke=1)

    for P in (16, 32):
        cands = initial_candidates(g)[:P]
        assert len(cands) == P, f"graph yields only {len(cands)} candidates"
        taus = [tau_threshold(8, 1.0, p.k) for p in cands]
        seq_cfg = MiningConfig(sigma=8, lam=1.0, metric="mis", complete=True,
                               match=cfg, execution="sequential")

        # warmup compiles both data planes
        seq = [evaluate_pattern(g, dev_g, p, t, seq_cfg)
               for p, t in zip(cands, taus)]
        bat, _, _ = evaluate_level_batched(
            g, dev_g, cands, taus, "mis", cfg, complete=True)
        assert [s.support for s in seq] == [o.support for o in bat]

        t0 = time.perf_counter()
        for _ in range(reps):
            for p, t in zip(cands, taus):
                evaluate_pattern(g, dev_g, p, t, seq_cfg)
        t_seq = (time.perf_counter() - t0) / reps

        t0 = time.perf_counter()
        for _ in range(reps):
            evaluate_level_batched(g, dev_g, cands, taus, "mis", cfg,
                                   complete=True)
        t_bat = (time.perf_counter() - t0) / reps

        rows.append({
            "name": f"exec_time/batched_level/n{n}/P{P}",
            "us_per_call": round(t_bat * 1e6, 1),
            "derived": round(t_seq / t_bat, 2),   # speedup (x)
            "sequential_us": round(t_seq * 1e6, 1),
            "batched_us": round(t_bat * 1e6, 1),
            "speedup": round(t_seq / t_bat, 2),
        })


def _bench_planner(rows):
    """Execution-planner A/B cells (PR 4 tentpole acceptance).

    Two regimes, both end-to-end ``mine()`` runs so the planner sees real
    per-level telemetry:

      * ``planner/compute_bound_P1`` — single-label bounded-degree graph
        (1–2 candidates per level) under a deliberately oversized
        graph-global geometry (big cap): one pattern's block saturates the
        device, the batched plane has nothing to amortize, and the win
        comes from the planner's occupancy-derived per-level ``cap``.
        Target: auto ≥ 1.3× over forced batched (derived column).
      * ``planner/level_P{16,32}`` — the dispatch-bound regime of the
        PR 1 cells: auto must keep the batched plane's ≥2× win over
        sequential (derived) while staying within 5% of forced batched
        (``vs_best`` ≥ 0.95).
    """
    import dataclasses

    from repro.core import MatchConfig, MiningConfig, mine

    def timed_mine(g, reps, **kw):
        cfg = MiningConfig(**kw)
        res = mine(g, cfg)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = mine(g, cfg)
        return (time.perf_counter() - t0) / reps, res

    reps = bench_iters(3, smoke=1)

    # --- compute-bound: P∈{1,2} candidates, oversized cap -----------------
    n = 2000 if SMOKE else 8000
    g1 = _bounded_degree_graph(n, deg=2, n_labels=1)
    big = dataclasses.replace(
        MatchConfig.for_graph(g1, cap=16384, root_block=256), two_phase=False)
    kw = dict(sigma=4, lam=1.0, metric="mis", max_pattern_size=3,
              complete=True, match=big)
    t = {}
    out = {}
    for ex in ("batched", "sequential", "auto"):
        t[ex], out[ex] = timed_mine(g1, reps, execution=ex, **kw)
    assert ([(p.k, s) for p, s in out["auto"].frequent]
            == [(p.k, s) for p, s in out["batched"].frequent]
            == [(p.k, s) for p, s in out["sequential"].frequent])
    best = min(t["batched"], t["sequential"])
    rows.append({
        "name": f"exec_time/planner/compute_bound_P1/n{n}",
        "us_per_call": round(t["auto"] * 1e6, 1),
        "derived": round(t["batched"] / t["auto"], 2),   # ≥1.3 target
        "sequential_us": round(t["sequential"] * 1e6, 1),
        "batched_us": round(t["batched"] * 1e6, 1),
        "vs_best": round(best / t["auto"], 3),           # ≥0.95 target
    })

    # --- dispatch-bound: the PR 1 P∈{16,32} cells, auto added -------------
    n = 2000 if SMOKE else 8000
    g2 = _bounded_degree_graph(n, deg=2, n_labels=8)
    cfg2 = MatchConfig.for_graph(g2, cap=64, root_block=64)
    for P in (16, 32):
        from repro.core.flexis import initial_candidates

        assert len(initial_candidates(g2)) >= P
        kw = dict(sigma=8, lam=1.0, metric="mis", max_pattern_size=2,
                  complete=True, match=cfg2)
        t = {}
        out = {}
        for ex in ("batched", "sequential", "auto"):
            # max_pattern_size=2 bounds the run to one level of ≥P
            # candidates; slice via batch_patterns like the PR 1 cell
            t[ex], out[ex] = timed_mine(g2, reps, execution=ex,
                                        batch_patterns=P, **kw)
        assert ([(p.k, s) for p, s in out["auto"].frequent]
                == [(p.k, s) for p, s in out["batched"].frequent])
        best = min(t["batched"], t["sequential"])
        rows.append({
            "name": f"exec_time/planner/level_P{P}/n{n}",
            "us_per_call": round(t["auto"] * 1e6, 1),
            "derived": round(t["sequential"] / t["auto"], 2),  # ≥2 target
            "sequential_us": round(t["sequential"] * 1e6, 1),
            "batched_us": round(t["batched"] * 1e6, 1),
            "vs_best": round(best / t["auto"], 3),             # ≥0.95 target
        })


def _bench_expansion_plane(rows):
    """One batched mining level under each expansion plane (PR 2 tentpole).

    `xla` is the production CPU path; `pallas_interp` runs the fused kernel
    in interpret mode (this container has no TPU), so its time is the
    interpreter's, not the hardware's — the row exists to pin *bit-exact
    parity* (parity=1.0) and to give TPU runs a ready-made A/B harness
    (set pallas_interpret=False there).
    """
    import dataclasses

    from repro.core import MatchConfig
    from repro.core.batched import evaluate_level_batched
    from repro.core.flexis import initial_candidates
    from repro.core.graph import DeviceGraph

    n = 1000 if SMOKE else 4000
    g = _bounded_degree_graph(n, deg=2, n_labels=8)
    dev_g = DeviceGraph.from_host(g)
    cfg_x = dataclasses.replace(
        MatchConfig.for_graph(g, cap=64, root_block=64), two_phase=False)
    cfg_p = dataclasses.replace(cfg_x, expansion="pallas")
    P = 8
    cands = initial_candidates(g)[:P]
    taus = [10**6] * len(cands)
    reps = bench_iters(3, smoke=1)

    outs = {}
    times = {}
    for name, cfg in (("xla", cfg_x), ("pallas_interp", cfg_p)):
        evaluate_level_batched(g, dev_g, cands, taus, "mis", cfg,
                               complete=True)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            outs[name], _, _ = evaluate_level_batched(
                g, dev_g, cands, taus, "mis", cfg, complete=True)
        times[name] = (time.perf_counter() - t0) / reps
    parity = float(all(
        (a.support, a.embeddings_found, a.overflowed)
        == (b.support, b.embeddings_found, b.overflowed)
        for a, b in zip(outs["xla"], outs["pallas_interp"])))
    for name in ("xla", "pallas_interp"):
        rows.append({
            "name": f"exec_time/expansion_plane/{name}/n{n}/P{P}",
            "us_per_call": round(times[name] * 1e6, 1),
            "derived": parity,  # 1.0 = planes bit-identical on this level
            "speedup": round(times["xla"] / times[name], 3),
        })


def _bench_sampled(rows):
    """Sampled plane vs the forced-batched oracle (ISSUE 7 tentpole).

    Real-σ regime on the gnutella stand-in: τ = σ·λ^(k−2) sits above the
    hidden-block bound (≈10.4 at f=0.25, ≈4.3 at f=0.5 — see
    `repro.core.sampled.ht_interval`), so the long tail of zero-mass and
    clearly-infrequent candidates prunes from the sample alone and only
    the patterns whose CI straddles τ pay the exact escalation pass.

    ``accuracy`` is 1.0 iff the frequent set + supports are identical to
    forced batched — the regression gate fails on anything else; the
    speedup (derived) target is ≥1.5× at fraction ≤0.5 on ≥1 cell
    (measured 1.6× at f=0.5: τ=20 sits above both hidden-block bounds,
    so the sample settles 32 of 40 candidates and only 8 escalate).

    ``root_block`` is forced small: the default `for_graph` geometry
    covers these scaled stand-ins with ONE root block, and a one-block
    level has nothing to sample — the cell must sit in the multi-block
    dispatch-bound regime the plane exists for.
    """
    import dataclasses

    from repro.core import MatchConfig, MiningConfig, canonical_key, mine
    from repro.data.synthetic import paper_dataset

    # smoke graph is ~31 vertices: σ=20 would trip the k·τ>n vertex bound
    # and evaluate nothing, so smoke runs a proportionally smaller σ
    scale = 0.005 if SMOKE else 0.02
    sigma, lam = (6 if SMOKE else 20), 1.0
    g = paper_dataset("gnutella", scale=scale, seed=0)
    match = dataclasses.replace(MatchConfig.for_graph(g, cap=4096),
                                root_block=4 if SMOKE else 8)
    base = dict(sigma=sigma, lam=lam, metric="mis", generation="merge",
                max_pattern_size=BENCH_MAX_SIZE, time_limit_s=600.0,
                match=match)
    reps = bench_iters(2, smoke=1)

    def timed(**kw):
        cfg = MiningConfig(**base, **kw)
        res = mine(g, cfg)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = mine(g, cfg)
        return (time.perf_counter() - t0) / reps, res

    def digest(res):
        return [(canonical_key(p), int(s)) for p, s in res.frequent]

    t_bat, ref = timed(execution="batched")
    for f in (0.25, 0.5):
        t_s, res = timed(execution="sampled", sample_fraction=f)
        esc = sum(int(v.get("sampled", {}).get("escalated", 0))
                  for v in res.per_level.values())
        pruned = sum(int(v.get("sampled", {}).get("pruned", 0))
                     for v in res.per_level.values())
        rows.append({
            "name": f"exec_time/sampled/gnutella/s{sigma}/f{f}",
            "us_per_call": round(t_s * 1e6, 1),
            "derived": round(t_bat / t_s, 2),            # speedup ≥1.5 target
            "batched_us": round(t_bat * 1e6, 1),
            "accuracy": float(digest(res) == digest(ref)),
            "escalated": esc,
            "pruned": pruned,
        })


def _bench_auto_sampled(rows):
    """Auto planner pricing the sampled plane end to end (ISSUE 10).

    Sample-favorable geometry: the dispatch-bound bounded-degree regime
    of the PR 1 cells, with *skewed* labels — a few hot labels carry the
    frequent pairs while a long tail of rare-label candidates sits far
    below τ and prunes from the sample alone.  τ clears the hidden-block
    bound (≈10.4 at f=0.25), so the auto planner's pricing row
    ``f·batched + E[esc]·((1−f)·batched + f·replay)`` beats the batched
    row and the level runs sampled *by the planner's own choice* — the
    rows assert that (a planner that silently stops picking the plane
    would otherwise keep green on forced-plane rows alone).

    ``accuracy`` is 1.0 iff the frequent set + supports equal forced
    batched; ``derived`` is the speedup over forced batched — blocking
    regression-gate targets are accuracy == 1.0 and ≥ 1.3× on at least
    the σ-high cell (measured 1.5×/2.2× at σ = 90/150 in smoke: 58/20 of
    222 candidates escalate, the rest settle inside the adaptive rounds).
    """
    from repro.core import MatchConfig, MiningConfig, build_graph, \
        canonical_key, mine

    n = 2000 if SMOKE else 8000
    rng = np.random.default_rng(0)
    src = np.repeat(np.arange(n), 2)
    dst = rng.integers(0, n, n * 2)
    # quadratically skewed labels: hot pairs stay frequent, the tail prunes
    labels = np.minimum((12 * rng.random(n) ** 2).astype(np.int64), 11)
    g = build_graph(n, np.stack([src, dst], 1), labels, undirected=True)
    match = MatchConfig.for_graph(g, cap=64, root_block=64)
    reps = bench_iters(3, smoke=1)

    def timed(**kw):
        cfg = MiningConfig(metric="mis", lam=1.0, max_pattern_size=2,
                           match=match, sample_fraction=0.25, **kw)
        res = mine(g, cfg)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = mine(g, cfg)
        return (time.perf_counter() - t0) / reps, res

    def digest(res):
        return [(canonical_key(p), int(s)) for p, s in res.frequent]

    # per-pair supports scale ~linearly with n: keep τ in the same spot
    # of the support distribution at full size
    for sigma in ((90, 150) if SMOKE else (360, 600)):
        t_bat, ref = timed(sigma=sigma, execution="batched")
        t_auto, res = timed(sigma=sigma, execution="auto")
        picked = [lvl for lvl, st in res.per_level.items()
                  if (st.get("plan") or {}).get("plane") == "sampled"]
        assert picked, f"auto never priced the sampled plane at sigma={sigma}"
        sd = [st["sampled"] for lvl, st in res.per_level.items()
              if st.get("sampled")]
        rows.append({
            "name": f"exec_time/auto_sampled/skew/n{n}/s{sigma}/f0.25",
            "us_per_call": round(t_auto * 1e6, 1),
            "derived": round(t_bat / t_auto, 2),         # speedup ≥1.3 target
            "batched_us": round(t_bat * 1e6, 1),
            "accuracy": float(digest(res) == digest(ref)),
            "escalated": sum(int(d.get("escalated", 0)) for d in sd),
            "pruned": sum(int(d.get("pruned", 0)) for d in sd),
        })


def main() -> None:
    rows = []
    _bench_batched_level(rows)
    _bench_planner(rows)
    _bench_expansion_plane(rows)
    for ds in BENCH_DATASETS:
        for sigma in SUPPORTS:
            for name, kw in VARIANTS:
                res = run_mine(ds, sigma=sigma, **kw)
                rows.append({
                    "name": f"exec_time/{ds}/s{sigma}/{name}",
                    "us_per_call": round(res.elapsed_s * 1e6, 1),
                    "derived": len(res.frequent),
                    "searched": res.searched,
                    "timed_out": res.timed_out,
                })
    # last: their forced-small root_block geometries compile programs the
    # cells above never reuse — running them earlier would perturb their
    # (compile-dominated) single-shot timings
    _bench_sampled(rows)
    _bench_auto_sampled(rows)
    emit(rows, ["name", "us_per_call", "derived", "searched", "timed_out",
                "sequential_us", "batched_us", "speedup", "vs_best",
                "accuracy", "escalated", "pruned"])


if __name__ == "__main__":
    main()
