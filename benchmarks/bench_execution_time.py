"""Paper Fig 9/10 — execution time vs support, FLEXIS (λ sweep) vs the
MNI (GraMi-like) and fractional (T-FSM-like) baselines, same runtime."""
from __future__ import annotations

from .common import BENCH_DATASETS, emit, run_mine

SUPPORTS = (6, 8, 12)
VARIANTS = [
    ("flexis_0.4", dict(metric="mis", lam=0.4, generation="merge")),
    ("flexis_1.0", dict(metric="mis", lam=1.0, generation="merge")),
    ("mni_edge_ext(GraMi-like)", dict(metric="mni", generation="edge_ext")),
    ("frac_edge_ext(T-FSM-like)", dict(metric="frac", generation="edge_ext")),
]


def main() -> None:
    rows = []
    for ds in BENCH_DATASETS:
        for sigma in SUPPORTS:
            for name, kw in VARIANTS:
                res = run_mine(ds, sigma=sigma, **kw)
                rows.append({
                    "name": f"exec_time/{ds}/s{sigma}/{name}",
                    "us_per_call": round(res.elapsed_s * 1e6, 1),
                    "derived": len(res.frequent),
                    "searched": res.searched,
                    "timed_out": res.timed_out,
                })
    emit(rows, ["name", "us_per_call", "derived", "searched", "timed_out"])


if __name__ == "__main__":
    main()
