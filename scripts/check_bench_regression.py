#!/usr/bin/env python3
"""Diff a fresh BENCH_smoke.json against the committed trajectory.

    python scripts/check_bench_regression.py BASELINE FRESH \
        [--max-ratio 1.3] [--families exec_time/batched_level/ ...]

Gate semantics (the blocking CI bench-smoke job):

  * for every gated row present in BOTH files, ``fresh.us_per_call`` must
    be ≤ ``max_ratio × baseline.us_per_call`` — slower than that fails;
  * a gated baseline row MISSING from the fresh run fails (a silently
    dropped benchmark would otherwise pass forever);
  * new rows, faster rows, and rows outside the gated families are
    reported but never fail;
  * parity rows additionally fail on parity != 1.0 (bit-exactness is not
    a timing and gets no tolerance);
  * accuracy rows (the sampled plane's exactness-via-escalation contract)
    fail on accuracy != 1.0 — including rows only present in the FRESH
    file, so a newly added sampled cell can never land inexact.

Timing families are gated with generous headroom (default 1.3×) because
CI runners are noisy; the point is catching step-function regressions
(a plane decision gone wrong, a lost program-cache hit), not 5% drift.
No third-party deps — runs on a bare checkout like scripts/check_links.py.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_FAMILIES = (
    "exec_time/batched_level/",
    "exec_time/gnutella/",
    "exec_time/sampled/",
    "exec_time/auto_sampled/",
)


def _rows(trajectory: dict) -> dict:
    return {r["name"]: r for r in trajectory.get("rows", [])}


def check(baseline: dict, fresh: dict, *, max_ratio: float = 1.3,
          families=DEFAULT_FAMILIES):
    """Returns (failures, notes) — lists of human-readable strings."""
    base_rows, fresh_rows = _rows(baseline), _rows(fresh)
    failures, notes = [], []

    for name, b in sorted(base_rows.items()):
        gated = any(name.startswith(f) for f in families)
        f = fresh_rows.get(name)
        if f is None:
            (failures if gated else notes).append(
                f"MISSING  {name}: row present in baseline, absent in fresh")
            continue
        if b.get("parity") is not None or f.get("parity") is not None:
            if f.get("parity") != 1.0:
                failures.append(
                    f"PARITY   {name}: parity={f.get('parity')} (want 1.0)")
            continue
        if b.get("accuracy") is not None or f.get("accuracy") is not None:
            if f.get("accuracy") != 1.0:
                failures.append(
                    f"ACCURACY {name}: accuracy={f.get('accuracy')} "
                    f"(want 1.0 — sampled plane must match the oracle)")
            # accuracy rows are still timing-gated below
        bt, ft = b.get("us_per_call"), f.get("us_per_call")
        if not bt or not ft or bt <= 0:
            continue
        ratio = ft / bt
        line = f"{name}: {bt:.1f}us → {ft:.1f}us ({ratio:.2f}x)"
        if gated and ratio > max_ratio:
            failures.append(f"SLOWER   {line} > {max_ratio}x gate")
        elif ratio > max_ratio:
            notes.append(f"slower (ungated) {line}")
    for name in sorted(set(fresh_rows) - set(base_rows)):
        f = fresh_rows[name]
        if f.get("accuracy") is not None and f.get("accuracy") != 1.0:
            failures.append(
                f"ACCURACY {name}: accuracy={f.get('accuracy')} "
                f"(want 1.0 — new sampled rows get no grace period)")
        notes.append(f"new row  {name}")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_smoke.json")
    ap.add_argument("fresh", help="freshly generated BENCH_smoke.json")
    ap.add_argument("--max-ratio", type=float, default=1.3,
                    help="fail gated rows slower than this ratio (def 1.3)")
    ap.add_argument("--families", nargs="*", default=list(DEFAULT_FAMILIES),
                    help="row-name prefixes the gate blocks on")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    failures, notes = check(baseline, fresh, max_ratio=args.max_ratio,
                            families=args.families)
    for n in notes:
        print(f"note: {n}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        print(f"bench regression gate: {len(failures)} failure(s) "
              f"(gate {args.max_ratio}x on {', '.join(args.families)})")
        return 1
    print(f"bench regression gate: OK "
          f"({len(baseline.get('rows', []))} baseline rows checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
