#!/usr/bin/env python3
"""Markdown link checker for the docs tree (CI `docs` job; no deps).

Scans README.md, ROADMAP.md, CHANGES.md, PAPER(S).md and everything under
docs/ for inline markdown links `[text](target)`:

  * relative file targets must exist (anchors stripped);
  * `#anchor` / `file.md#anchor` targets must match a heading slug in the
    target document;
  * absolute URLs (http/https/mailto) are recorded but not fetched — CI has
    no network guarantee and docs shouldn't flake on remote outages.

Exit 0 if clean, 1 with a per-link report otherwise.

    python scripts/check_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
SCAN = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md", "PAPERS.md",
        "ISSUE.md")


def slugify(heading: str) -> str:
    """GitHub-style heading → anchor slug (close enough for our docs)."""
    s = re.sub(r"[`*_~]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def strip_fenced_blocks(text: str) -> str:
    """Drop ``` fenced code blocks (their '# lines' are not headings)."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def heading_slugs(md_path: Path) -> set:
    text = strip_fenced_blocks(md_path.read_text())
    return {slugify(h) for h in HEADING_RE.findall(text)}


def check_file(md: Path, root: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if path_part and not dest.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
            continue
        if anchor:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ""):
                continue  # anchors into non-markdown are out of scope
            if slugify(anchor) not in heading_slugs(dest):
                errors.append(
                    f"{md.relative_to(root)}: missing anchor -> {target}")
    return errors


def main(argv=None) -> int:
    root = Path(argv[0]) if argv else Path(__file__).resolve().parents[1]
    files = [root / f for f in SCAN if (root / f).exists()]
    files += sorted((root / "docs").glob("**/*.md"))
    errors = []
    for md in files:
        errors += check_file(md, root)
    for e in errors:
        print(f"ERROR: {e}")
    print(f"[check_links] {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
