"""FLEXIS × GNN: frequent motifs as node features for GraphSAGE.

    PYTHONPATH=src python examples/mine_motifs_gnn.py

Where the paper's technique meets the assigned GNN architectures
(DESIGN.md §5): mine frequent patterns from a graph, build per-node
motif-participation counts from the matcher's embeddings, concatenate them
to the node features, and train GraphSAGE — mining and message passing
share the same CSR + segment-op substrate.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MatchConfig, MiningConfig, make_plan, match_block, mine
from repro.core.graph import DeviceGraph
from repro.data.synthetic import rmat_graph
from repro.models.gnn.common import GraphBatch
from repro.models.gnn.graphsage import SAGEConfig, sage_init, sage_loss
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def motif_features(g, patterns, cfg):
    """(n, |patterns|) counts of pattern embeddings through each vertex."""
    dev_g = DeviceGraph.from_host(g)
    feats = np.zeros((g.n, len(patterns)), np.float32)
    for j, pat in enumerate(patterns):
        plan = make_plan(pat, g)
        for b in range(0, g.n, cfg.root_block):
            emb, count, _, _, _ = match_block(dev_g, plan, jnp.int32(b), cfg)
            rows = np.asarray(emb[: int(count)]).reshape(-1)
            np.add.at(feats[:, j], rows[rows >= 0], 1.0)
    return feats


def main():
    g = rmat_graph(400, 2400, n_labels=3, seed=1, undirected=True)
    print(f"graph: |V|={g.n} |E|={g.n_edges}")

    mcfg = MatchConfig.for_graph(g, cap=4096)
    res = mine(g, MiningConfig(sigma=6, lam=0.5, metric="mis",
                               max_pattern_size=3, match=mcfg))
    motifs = [p for p, _ in res.frequent if p.k == 3][:8]
    print(f"mined {len(res.frequent)} frequent patterns; "
          f"using {len(motifs)} 3-vertex motifs as features")

    mf = motif_features(g, motifs, mcfg)
    base = np.eye(g.n_labels, dtype=np.float32)[g.labels]
    x = np.concatenate([base, mf / (1 + mf.max(0, keepdims=True))], axis=1)

    # node classification: predict the label from structure+motifs
    gb = GraphBatch(
        x=jnp.asarray(x),
        edge_src=jnp.asarray(np.repeat(np.arange(g.n), np.diff(g.out_indptr)),
                             jnp.int32),
        edge_dst=jnp.asarray(g.out_indices, jnp.int32),
        edge_mask=jnp.ones((g.n_edges,), bool),
        node_mask=jnp.ones((g.n,), bool),
        graph_ids=jnp.zeros((g.n,), jnp.int32), n_graphs=1,
        targets=jnp.asarray(g.labels, jnp.int32))

    cfg = SAGEConfig(d_in=x.shape[1], d_hidden=32, n_classes=g.n_labels)
    params = sage_init(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=5e-3, total_steps=60, warmup_steps=5)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lambda p: sage_loss(p, cfg, gb))(params)
        params, opt = adamw_update(opt_cfg, grads, opt, params)
        return loss, params, opt

    for i in range(60):
        loss, params, opt = step(params, opt)
        if i % 15 == 0:
            print(f"  step {i:3d} loss {float(loss):.4f}")
    print(f"final loss {float(loss):.4f} (motif features wired end to end)")


if __name__ == "__main__":
    main()
