"""Serve a small LM with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py

Builds the reduced mixtral (MoE + sliding window — the interesting serving
path), prefills a batch of prompts, then decodes tokens step by step with
the rolling-window cache, reporting per-step latency.
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mixtral_8x7b import REDUCED as CFG
from repro.models.transformer import (
    init_decode_cache, transformer_apply, transformer_decode, transformer_init,
)


def main():
    B, prompt_len, gen_len, max_seq = 4, 32, 16, 128
    rng = np.random.default_rng(0)
    params = transformer_init(jax.random.key(0), CFG)

    prompts = jnp.asarray(rng.integers(0, CFG.vocab, (B, prompt_len)), jnp.int32)

    # --- prefill: run the full prompt, then replay it into the cache -------
    t0 = time.monotonic()
    logits, _ = jax.jit(lambda p, t: transformer_apply(p, CFG, t))(params, prompts)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    print(f"prefill B={B} len={prompt_len}: {time.monotonic() - t0:.2f}s")

    cache = init_decode_cache(CFG, B, max_seq)
    decode = jax.jit(lambda p, c, t, pos: transformer_decode(p, CFG, c, t, pos))
    # replay prompt tokens through the decode path to fill the cache
    for i in range(prompt_len):
        _, cache = decode(params, cache, prompts[:, i:i + 1],
                          jnp.full((B,), i, jnp.int32))

    # --- decode loop ---------------------------------------------------------
    toks = [next_tok]
    times = []
    for step in range(gen_len):
        pos = jnp.full((B,), prompt_len + step, jnp.int32)
        t0 = time.monotonic()
        logits, cache = decode(params, cache, toks[-1][:, None], pos)
        logits.block_until_ready()
        times.append(time.monotonic() - t0)
        toks.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))

    out = np.stack([np.asarray(t) for t in toks], axis=1)
    print(f"decoded {gen_len} tokens/seq; "
          f"median step latency {np.median(times) * 1e3:.1f} ms "
          f"(batch {B}, rolling window {CFG.window})")
    print("sample token ids:", out[0][:12], "…")


if __name__ == "__main__":
    main()
