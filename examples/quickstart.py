"""Quickstart — the paper's Figure 1 worked example, end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds the 7-vertex data graph D and pattern P1 from the paper, counts
support under every metric (MNI = 3, exact MIS = 2, mIS ∈ {1,2}, fractional
≤ MNI), then mines D at σ=2 and shows P1 coming out frequent — including
the λ-slider trade-off of §3.1.1.
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    MatchConfig, MiningConfig, build_graph, canonical_key, mine, paper_fig1,
    tau_threshold,
)
from repro.core.metrics import (
    enumerate_embeddings_host, exact_mis, greedy_mis_host,
)


def main():
    p1, edges, labels = paper_fig1()
    g = build_graph(7, edges, labels)
    print(f"data graph D: |V|={g.n} |E|={g.n_edges}")
    print(f"pattern P1:   labels={p1.labels.tolist()} edges={p1.edges()}")

    embs = enumerate_embeddings_host(g, p1)
    print(f"\nembeddings of P1 in D: {embs.shape[0]} (paper: 6)")
    print(f"  exact MIS  = {exact_mis(embs)}            (paper: 2)")
    print(f"  greedy mIS = {len(greedy_mis_host(embs))}            (paper: 1 or 2)")

    # τ interpolation (Eq. 1)
    print("\nEq. 1 thresholds for a 3-vertex pattern at sigma=2:")
    for lam in (0.0, 0.25, 1.0):
        print(f"  lambda={lam:4}: tau={tau_threshold(2, lam, 3)}")

    # mine D at sigma=2, lambda=1 — P1 must come out frequent with support 2
    cfg = MiningConfig(sigma=2, lam=1.0, metric="mis", max_pattern_size=3,
                       match=MatchConfig.for_graph(g, cap=256, root_block=8))
    res = mine(g, cfg)
    sup = {canonical_key(p): s for p, s in res.frequent}
    print(f"\nmined {len(res.frequent)} frequent patterns "
          f"(searched {res.searched} candidates)")
    print(f"P1 frequent: {canonical_key(p1) in sup} "
          f"(support={sup.get(canonical_key(p1))}, expect 2)")

    # sigma=3: MNI says frequent (3 ≥ 3) but mIS correctly rejects (2 < 3)
    cfg3 = MiningConfig(sigma=3, lam=1.0, metric="mis", max_pattern_size=3,
                        match=MatchConfig.for_graph(g, cap=256, root_block=8))
    r3 = mine(g, cfg3)
    print(f"\nat sigma=3 (mIS): P1 frequent = "
          f"{canonical_key(p1) in {canonical_key(p) for p, _ in r3.frequent}} "
          f"(MNI would overestimate and accept)")


if __name__ == "__main__":
    main()
