"""Train a language model end to end (loss ↓, checkpoints, resume).

    PYTHONPATH=src python examples/train_lm.py            # ~3M params, fast
    PYTHONPATH=src python examples/train_lm.py --m100     # ~100M params

Demonstrates the full production path on CPU: sharded-ready model code,
AdamW + schedule, bf16 compute, async checkpointing and auto-resume (kill
it mid-run and start it again).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config (slow on CPU; the real target "
                    "is a pod — the dry-run proves those shardings)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.m100:
        # ~100M params: register an ad-hoc arch by patching the reduced cfg
        import dataclasses

        from repro.configs import qwen3_1_7b as q

        cfg100 = dataclasses.replace(
            q.REDUCED, name="qwen3-100m", vocab=50_000, d_model=640,
            n_layers=10, n_heads=10, n_kv_heads=5, head_dim=64, d_ff=2560)
        q.ARCH.reduced_cfg = cfg100
        steps = args.steps or 300
        argv = ["--arch", "qwen3-1.7b", "--reduced", "--steps", str(steps),
                "--batch", "4", "--seq", "256", "--ckpt-dir",
                "/tmp/repro_ckpt_100m", "--log-every", "5"]
    else:
        steps = args.steps or 200
        argv = ["--arch", "qwen3-1.7b", "--reduced", "--steps", str(steps),
                "--batch", "8", "--seq", "128", "--ckpt-dir",
                "/tmp/repro_ckpt_small", "--log-every", "20"]
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    main()
