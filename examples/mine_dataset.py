"""End-to-end mining driver (the paper's kind of workload).

    PYTHONPATH=src python examples/mine_dataset.py [--dataset gnutella]

Synthesizes a structure-matched stand-in of a paper dataset, mines it with
FLEXIS (mIS, merge generation) and with the GraMi/T-FSM-like baselines,
and prints the comparison the paper's Figures 9-11 make.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import MatchConfig, MiningConfig, mine
from repro.data.synthetic import paper_dataset


def run(name, g, **kw):
    cfg = MiningConfig(match=MatchConfig.for_graph(g, cap=4096),
                       max_pattern_size=3, time_limit_s=300.0, **kw)
    res = mine(g, cfg)
    print(f"  {name:28s} time={res.elapsed_s:7.2f}s "
          f"frequent={len(res.frequent):4d} searched={res.searched:5d} "
          f"peak={res.peak_device_bytes / 2**20:6.1f}MiB"
          f"{' TIMEOUT' if res.timed_out else ''}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gnutella")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--sigma", type=int, default=8)
    args = ap.parse_args()

    g = paper_dataset(args.dataset, scale=args.scale)
    print(f"{args.dataset}×{args.scale}: |V|={g.n} |E|={g.n_edges}")
    run("FLEXIS (mIS λ=0.4, merge)", g, sigma=args.sigma, lam=0.4, metric="mis")
    run("FLEXIS (mIS λ=1.0, merge)", g, sigma=args.sigma, lam=1.0, metric="mis")
    run("GraMi-like (MNI, edge-ext)", g, sigma=args.sigma, metric="mni",
        generation="edge_ext")
    run("T-FSM-like (frac, edge-ext)", g, sigma=args.sigma, metric="frac",
        generation="edge_ext")


if __name__ == "__main__":
    main()
